"""Sharded + chunked sweep execution (DESIGN.md §13): one design-lane grid
run three ways — single-device baseline, lanes sharded across the local
devices (``sweep(..., shard=True)``), and lanes streamed through one
compiled program in fixed-shape chunks (``sweep(..., chunk=N)``).

Run with ``--devices 8`` to put 8 virtual CPU devices behind the lane mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, applied by the
pre-import shim).  Virtual CPU devices partition XLA's *programs*, not the
host's cores: on a 1-core host the sharded lanes still time-share the same
core, so the ``shard/speedup_*`` rows measure partitioning overhead there,
not parallel speedup — the ``shard/host_cpus`` row records the physical
ceiling next to the numbers.  The rows that matter everywhere are the
equality row (sharded/chunked results are bit-for-bit equal to the
baseline) and the compile counters feeding the CC001 gate (chunking and
sharding must not add compiles per policy shape).
"""
from ._devices import apply_devices_flag

DEVICES = apply_devices_flag()  # must precede the repro imports (jax)

import os

import numpy as np

from repro.obs import bench_cli, scaled, timer
from repro.obs import metrics as _metrics
from repro.scenario import Scenario, TraceSpec, sweep
from repro.dse.space import DesignPoint

POLICY = "etf"
NUM_LANES = 32          # design lanes (cross-cluster penalty ladder)
NUM_SEEDS = 4
NUM_JOBS = 48


def _bitexact(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("avg_latency_us", "makespan_us", "energy_j",
                         "peak_temp_c", "busy_per_pe_us"))


def run(smoke: bool = False):
    lanes = scaled(NUM_LANES, 8, smoke)
    seeds = tuple(range(scaled(NUM_SEEDS, 2, smoke)))
    num_jobs = scaled(NUM_JOBS, 12, smoke)
    chunk = max(1, lanes // 4)
    # identical PE counts per lane (no pad_pes interplay): the design axis
    # walks the interconnect penalty, which retimes every comm edge
    points = [DesignPoint(cross_cluster_penalty=1.0 + 0.25 * i)
              for i in range(lanes)]
    scn = Scenario(apps=("wifi_tx",), scheduler=POLICY, governor="design",
                   trace=TraceSpec(num_jobs=num_jobs))
    axes = {"design": points, "seed": seeds}

    results, rows = {}, []
    mesh_width = 1
    for mode, kw in [("base", dict(shard=False)),
                     ("sharded", dict(shard=True)),
                     ("chunked", dict(shard=False, chunk=chunk))]:
        t = timer(f"bench.shard.{mode}")
        with t:                                   # cold: includes compile
            results[mode] = sweep(scn, axes=axes, **kw)
        cold_us = t.last_us
        with t:                                   # warm: cached program
            results[mode] = sweep(scn, axes=axes, **kw)
        if mode == "sharded":                     # before chunked overwrites
            mesh_width = _metrics.counter("scenario.shard.devices").value
        rows.append((f"shard/{mode}_warm_us", t.last_us,
                     f"cold={cold_us:.0f}us"))
        rows.append((f"shard/{mode}_lanes_per_s",
                     lanes * len(seeds) / max(t.last_s, 1e-9),
                     f"{lanes}x{len(seeds)}lanes"))

    base = timer("bench.shard.base").last_s
    for mode in ("sharded", "chunked"):
        rows.append((f"shard/speedup_{mode}",
                     base / max(timer(f"bench.shard.{mode}").last_s, 1e-9),
                     f"{DEVICES}dev warm-vs-base"))
    rows.append(("shard/bitexact",
                 float(_bitexact(results["base"], results["sharded"])
                       and _bitexact(results["base"], results["chunked"])),
                 "1.0=sharded&chunked equal baseline"))
    rows.append(("shard/devices", mesh_width,
                 "lane-mesh width of the sharded mode"))
    rows.append(("shard/pad_lanes",
                 _metrics.counter("scenario.shard.pad_lanes").value,
                 "inert lanes added (dropped on exit)"))
    rows.append(("shard/chunks",
                 _metrics.counter("scenario.sweep.chunks").value,
                 f"chunk={chunk} over {lanes} lanes"))
    rows.append(("shard/host_cpus", float(os.cpu_count() or 1),
                 "physical ceiling: virtual devices time-share these"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "shard", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
