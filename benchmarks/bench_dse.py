"""DSE throughput: design-points/sec for the batched (vmap × vmap) evaluator
vs a per-design loop — the scale story the dse subsystem exists for."""
import time

import numpy as np

from repro.core import build_tables, get_application, poisson_trace, \
    simulate_jax
from repro.dse import (DesignSpace, build_design_batch, evaluate,
                       peak_temperature_grid, simulate_design_batch,
                       stack_traces)

NUM_DESIGNS = 64
NUM_TRACES = 4
NUM_JOBS = 32
RATE = 20.0
POLICY = "etf"
APPS = ["wifi_tx", "wifi_rx"]


def run():
    apps = [get_application(n) for n in APPS]
    traces = [poisson_trace(RATE, NUM_JOBS, APPS, seed=s)
              for s in range(NUM_TRACES)]
    points = DesignSpace().sample_lhs(NUM_DESIGNS, seed=0)
    rows = []

    # batched evaluator: cold (compile) and warm
    batch = build_design_batch(points, apps)
    arrival, app_idx = stack_traces(traces)
    t0 = time.perf_counter()
    res = evaluate(points, apps, traces, policy=POLICY, batch=batch)
    cold = time.perf_counter() - t0

    def batched_once():
        out = simulate_design_batch(batch, POLICY, arrival, app_idx)
        temps = peak_temperature_grid(out, batch.node_of_pe,
                                      batch.tables.power_active,
                                      batch.tables.power_idle)
        np.asarray(temps)                            # block until done

    def batched_sim_only():
        np.asarray(simulate_design_batch(batch, POLICY, arrival,
                                         app_idx)["avg_job_latency_us"])

    batched_once()     # compile the standalone (unfused) programs untimed
    t0 = time.perf_counter()
    batched_once()
    warm = time.perf_counter() - t0
    batched_sim_only()
    t0 = time.perf_counter()
    batched_sim_only()
    warm_sim = time.perf_counter() - t0
    rows.append(("dse/batched/cold", cold * 1e6 / NUM_DESIGNS,
                 "us_per_design_incl_compile"))
    rows.append(("dse/batched/warm", warm * 1e6 / NUM_DESIGNS,
                 "us_per_design"))
    rows.append(("dse/batched/throughput", NUM_DESIGNS / warm,
                 "design_points_per_sec"))

    # per-design loop on the same workload (the baseline being replaced);
    # a subset is enough — each design re-jits for its own PE count, so
    # time a second (warm) pass for the apples-to-apples speedup row
    subset = points[:8]
    per_design_tables = [build_tables(p.to_db(), apps, governor=p.governor())
                         for p in subset]

    def loop_once():
        for tables in per_design_tables:
            for tr in traces:
                np.asarray(simulate_jax(tables, POLICY, tr.arrival_us,
                                        tr.app_index)["avg_job_latency_us"])

    t0 = time.perf_counter()
    loop_once()                                      # compiles per design
    loop_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    loop_once()
    loop_warm = time.perf_counter() - t0
    rows.append(("dse/loop/cold", loop_cold * 1e6 / len(subset),
                 "us_per_design_incl_compile"))
    rows.append(("dse/loop/warm", loop_warm * 1e6 / len(subset),
                 "us_per_design"))
    # speedup compares simulation-only on both sides (the loop baseline has
    # no thermal pass); dse/batched/warm above includes the thermal scan
    rows.append(("dse/speedup_vs_loop",
                 (loop_warm / len(subset)) / (warm_sim / NUM_DESIGNS),
                 "x_batched_warm_vs_loop_warm_sim_only"))
    rows.append(("dse/front_size", float(res.front_mask().sum()),
                 "non_dominated_designs"))
    return rows
