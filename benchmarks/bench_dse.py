"""DSE throughput: design-points/sec for the batched (vmap × vmap) sweep
vs a per-design ``run()`` loop — the scale story the dse subsystem exists
for.  Both sides are declared through one ``Scenario``; both include the
fused RC thermal co-simulation."""
from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

from repro.dse import DesignSpace, build_design_batch, evaluate
from repro.obs import bench_cli, scaled, timer
from repro.scenario import Scenario, TraceSpec, run as run_scenario, sweep

NUM_DESIGNS = 64
NUM_TRACES = 4
NUM_JOBS = 32
RATE = 20.0
POLICY = "etf"


def run(smoke: bool = False):
    num_designs = scaled(NUM_DESIGNS, 8, smoke)
    base = Scenario(apps=("wifi_tx", "wifi_rx"), scheduler=POLICY,
                    governor="design",
                    trace=TraceSpec(rate_jobs_per_ms=RATE,
                                    num_jobs=scaled(NUM_JOBS, 8, smoke)))
    points = DesignSpace().sample_lhs(num_designs, seed=0)
    seeds = list(range(scaled(NUM_TRACES, 2, smoke)))
    axes = {"design": points, "seed": seeds}
    rows = []

    # one stacked design batch shared by the sweep and the Pareto front
    batch = build_design_batch(points, base.applications())

    # batched sweep: cold (compile) and warm
    t = timer("bench.dse.batched")
    with t:
        sweep(base, axes=axes, design_batch=batch)
    cold = t.last_s
    with t:
        sweep(base, axes=axes, design_batch=batch)
    warm = t.last_s
    rows.append(("dse/batched/cold", cold * 1e6 / num_designs,
                 "us_per_design_incl_compile"))
    rows.append(("dse/batched/warm", warm * 1e6 / num_designs,
                 "us_per_design"))
    rows.append(("dse/batched/throughput", num_designs / warm,
                 "design_points_per_sec"))

    # per-design run() loop on the same workload (the baseline the batch
    # replaces); a subset is enough — each design re-jits for its own PE
    # count, so time a second (warm) pass for the apples-to-apples row
    subset = points[:8]

    def loop_once():
        for p in subset:
            for s in seeds:
                run_scenario(base.replace(design=p).with_seed(s),
                             backend="jax")

    t_loop = timer("bench.dse.loop")
    with t_loop:
        loop_once()                                  # compiles per design
    loop_cold = t_loop.last_s
    with t_loop:
        loop_once()
    loop_warm = t_loop.last_s
    rows.append(("dse/loop/cold", loop_cold * 1e6 / len(subset),
                 "us_per_design_incl_compile"))
    rows.append(("dse/loop/warm", loop_warm * 1e6 / len(subset),
                 "us_per_design"))
    rows.append(("dse/speedup_vs_loop",
                 (loop_warm / len(subset)) / (warm / num_designs),
                 "x_batched_warm_vs_loop_warm"))

    # Pareto front over the same scenario grid (facade-delegating evaluate)
    traces = [base.with_seed(s).job_trace() for s in seeds]
    res = evaluate(points, base.applications(), traces, policy=POLICY,
                   batch=batch)
    rows.append(("dse/front_size", float(res.front_mask().sum()),
                 "non_dominated_designs"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "dse", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
