"""Paper §2 DTPM capability: energy/latency trade-off across DVFS governors
(the power/thermal exploration the framework exists to enable)."""
from repro.core import (get_governor, get_scheduler, make_soc_table2,
                        poisson_trace, simulate, thermal, wifi_tx)


def run():
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(20.0, 150, ["wifi_tx"], seed=0)
    rows = []
    for gov in ["performance", "powersave", "ondemand"]:
        res = simulate(db, [app], trace, get_scheduler("etf"),
                       get_governor(gov))
        rows.append((f"dtpm/{gov}/latency", res.avg_job_latency_us,
                     "avg_job_latency_us"))
        rows.append((f"dtpm/{gov}/energy", res.energy.total_energy_mj,
                     "total_mj"))
        rows.append((f"dtpm/{gov}/power", res.energy.avg_power_w, "avg_W"))
        # steady-state temperature at the power split the schedule realised
        # (per-PE energy over the makespan, aggregated per thermal node)
        p = thermal.node_power_split(db, res.energy.energy_per_pe_mj,
                                     res.makespan_us)
        rows.append((f"dtpm/{gov}/t_steady", thermal.steady_state(p)[0],
                     "big_cluster_C"))
    return rows
