"""Paper §2 DTPM capability: energy/latency trade-off across DVFS governors
(the power/thermal exploration the framework exists to enable)."""
from repro.core import thermal
from repro.scenario import Scenario, TraceSpec, run as run_scenario

SCN = Scenario(apps=("wifi_tx",),
               trace=TraceSpec(rate_jobs_per_ms=20.0, num_jobs=150, seed=0))


def run():
    db = SCN.soc()
    rows = []
    for gov in ["performance", "powersave", "ondemand"]:
        res = run_scenario(SCN.replace(governor=gov), backend="ref")
        rows.append((f"dtpm/{gov}/latency", res.avg_latency_us,
                     "avg_job_latency_us"))
        rows.append((f"dtpm/{gov}/energy", res.energy_j, "total_j"))
        rows.append((f"dtpm/{gov}/power", res.avg_power_w, "avg_W"))
        # steady-state temperature at the power split the schedule realised
        # (per-PE energy over the makespan, aggregated per thermal node)
        p = thermal.node_power_split(db, res.energy_report.energy_per_pe_j,
                                     res.makespan_us)
        rows.append((f"dtpm/{gov}/t_steady", thermal.steady_state(p)[0],
                     "big_cluster_C"))
    return rows
