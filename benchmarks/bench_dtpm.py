"""Paper §2 DTPM capability: energy/latency/temperature trade-off across
DVFS governors — now including the closed-loop dynamic policies the JAX
kernel runs (ondemand + thermal throttle), so the DTPM kernel's numbers and
warm wall-clock are benchmarked every PR.

Peak temperature comes straight off the ``Result`` surface: the reference
backend reports the schedule's steady-state read-out, the DTPM kernel the
inline RC loop its throttle feedback integrates (DESIGN.md §7).

``python -m benchmarks.bench_dtpm [--json PATH]`` runs this module alone and
optionally dumps the rows + run manifest as JSON (the CI perf artifact).
"""
from __future__ import annotations

from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

import dataclasses

from repro.obs import bench_cli, scaled, timer
from repro.scenario import Scenario, TraceSpec, run as run_scenario

SCN = Scenario(apps=("wifi_tx",),
               trace=TraceSpec(rate_jobs_per_ms=20.0, num_jobs=150, seed=0))

# (row label, governor, governor_params, backend)
CASES = [
    ("performance", "performance", (), "ref"),
    ("powersave", "powersave", (), "ref"),
    ("ondemand", "ondemand", (), "ref"),
    ("ondemand_jax", "ondemand", (), "jax"),
    # same thermal dilation with and without the cap, so the t_peak pair is
    # directly comparable and shows the cap binding (27 C < uncapped peak):
    # the closed loop trades latency for temperature
    ("ondemand_dt50ms_jax", "ondemand", (("thermal_dt_s", 0.05),), "jax"),
    ("throttle_jax", "throttle", (("thermal_cap_c", 27.0),
                                  ("thermal_dt_s", 0.05)), "jax"),
]


def run(smoke: bool = False):
    base = SCN.replace(trace=dataclasses.replace(
        SCN.trace, num_jobs=scaled(SCN.trace.num_jobs, 30, smoke)))
    rows = []
    t = timer("bench.dtpm.warm")
    for label, gov, params, backend in CASES:
        scn = base.replace(governor=gov, governor_params=params)
        res = run_scenario(scn, backend=backend)
        if backend == "jax":
            # warm wall-clock of the compiled DTPM kernel (compile excluded)
            with t:
                res = run_scenario(scn, backend=backend)
            rows.append((f"dtpm/{label}/wall", t.last_us, "us_warm"))
        rows.append((f"dtpm/{label}/latency", res.avg_latency_us,
                     "avg_job_latency_us"))
        rows.append((f"dtpm/{label}/energy", res.energy_j, "total_j"))
        rows.append((f"dtpm/{label}/power", res.avg_power_w, "avg_W"))
        rows.append((f"dtpm/{label}/t_peak", res.peak_temp_c, "peak_C"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "dtpm", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
