"""``--devices N`` — virtual host device count for benchmark runs.

XLA fixes the CPU device count when the backend initialises, which happens
on first ``jax`` use; ``--xla_force_host_platform_device_count`` is read
from ``XLA_FLAGS`` at that moment and never again.  So the flag MUST be
applied before the first ``import jax`` anywhere in the process — which is
why every benchmark module calls :func:`apply_devices_flag` at the very top
of its import list, before any ``repro`` import pulls JAX in, and why this
module itself must stay stdlib-only.

``python -m benchmarks.bench_<name> --devices 8`` then runs the benchmark
with 8 virtual CPU devices (the sharded-sweep lane mesh, DESIGN.md §13).
The default is 1 so all historical BENCH numbers stay comparable.
``repro.obs.bench.bench_cli`` declares the same flag for ``--help`` and
argument validation; this shim only peeks at ``sys.argv``.
"""
from __future__ import annotations

import os
import sys
from typing import Optional, Sequence


def parse_devices(argv: Sequence[str]) -> int:
    """The value of ``--devices N`` / ``--devices=N`` in ``argv`` (1 when
    absent).  Malformed values are left for argparse to reject later."""
    for i, arg in enumerate(argv):
        if arg == "--devices" and i + 1 < len(argv):
            val = argv[i + 1]
        elif arg.startswith("--devices="):
            val = arg.split("=", 1)[1]
        else:
            continue
        try:
            return max(1, int(val))
        except ValueError:
            return 1
    return 1


def apply_devices_flag(argv: Optional[Sequence[str]] = None) -> int:
    """Apply ``--devices N`` to ``XLA_FLAGS`` (idempotent); returns N.

    Must run before the first ``jax`` import: raises if JAX is already in
    ``sys.modules`` and more than one device was requested, instead of
    silently benchmarking on one device.
    """
    n = parse_devices(sys.argv[1:] if argv is None else argv)
    if n > 1:
        if "jax" in sys.modules:
            raise RuntimeError(
                "--devices must be applied before the first jax import: "
                "the XLA host device count is fixed at backend init. "
                "Call benchmarks._devices.apply_devices_flag() at the top "
                "of the benchmark module, before any repro import.")
        flag = f"--xla_force_host_platform_device_count={n}"
        prev = os.environ.get("XLA_FLAGS", "")
        if flag not in prev:
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()
    return n
