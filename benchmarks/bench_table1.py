"""Paper Table 1: WiFi-TX execution profiles on A7/A15/accelerators."""
from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

from repro.core.resources import ACC_FFT, ACC_SCRAMBLER, CPU_BIG, CPU_LITTLE
from repro.obs import bench_cli, timer
from repro.scenario import Scenario


def run():
    scn = Scenario(apps=("wifi_tx",))
    db = scn.soc()
    (app,) = scn.applications()
    rows = []
    t = timer("bench.table1.lookup")
    with t:
        for task in app.tasks:
            prof = db.profiles[task.name]
            rows.append((f"table1/{task.name}",
                         prof.get(CPU_LITTLE, float("nan")),
                         f"A15={prof.get(CPU_BIG)}us"
                         f" ACC={prof.get(ACC_SCRAMBLER, prof.get(ACC_FFT, '-'))}"))
    rows.append(("table1/lookup_total", t.last_us, f"{len(app.tasks)}tasks"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "table1", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
