"""Paper Table 1: WiFi-TX execution profiles on A7/A15/accelerators."""
import time

from repro.core.resources import ACC_FFT, ACC_SCRAMBLER, CPU_BIG, CPU_LITTLE
from repro.scenario import Scenario


def run():
    scn = Scenario(apps=("wifi_tx",))
    db = scn.soc()
    (app,) = scn.applications()
    rows = []
    t0 = time.perf_counter()
    for task in app.tasks:
        prof = db.profiles[task.name]
        rows.append((f"table1/{task.name}",
                     prof.get(CPU_LITTLE, float("nan")),
                     f"A15={prof.get(CPU_BIG)}us"
                     f" ACC={prof.get(ACC_SCRAMBLER, prof.get(ACC_FFT, '-'))}"))
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("table1/lookup_total", dt, f"{len(app.tasks)}tasks"))
    return rows
