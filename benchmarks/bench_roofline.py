"""Roofline terms per (arch × shape) from the dry-run artifacts (§Roofline).

Requires ``experiments/dryrun/*.json`` (run ``python -m repro.launch.dryrun
--all --both-meshes`` first); cells without artifacts are reported as absent.
"""
from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

from repro.configs import ARCHITECTURES, SHAPES
from repro.launch.roofline import cell_terms, load_cell
from repro.obs import bench_cli


def run():
    rows = []
    missing = 0
    for arch in sorted(ARCHITECTURES):
        for shape in sorted(SHAPES):
            rec = load_cell(arch, shape, "pod16x16")
            if rec is None:
                missing += 1
                continue
            if not rec.get("runnable"):
                rows.append((f"roofline/{arch}/{shape}", 0.0, "skipped"))
                continue
            t = cell_terms(rec)
            if t is None:
                continue
            step_us = max(t["t_compute"], t["t_memory"],
                          t["t_collective"]) * 1e6
            rows.append((f"roofline/{arch}/{shape}", step_us,
                         f"dom={t['dominant']}"
                         f";comp={t['t_compute']:.2e}s"
                         f";mem={t['t_memory']:.2e}s"
                         f";coll={t['t_collective']:.2e}s"
                         f";useful={t['model_flops_frac']:.2f}"))
    rows.append(("roofline/missing_cells", float(missing), "run_dryrun_first"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "roofline", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
