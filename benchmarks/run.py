"""Benchmark harness — one module per paper table/figure + the pod-scale
roofline.  Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import bench_table1, bench_fig3, bench_speedup, bench_dtpm, \
        bench_dse, bench_roofline, bench_faults, bench_shard
    print("name,us_per_call,derived")
    ok = True
    for mod in (bench_table1, bench_fig3, bench_speedup, bench_dtpm,
                bench_dse, bench_roofline, bench_faults, bench_shard):
        try:
            for name, val, derived in mod.run():
                print(f"{name},{val:.4f},{derived}")
        except Exception:                                  # noqa: BLE001
            ok = False
            print(f"{mod.__name__},nan,FAILED", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
