"""Simulation-speed benchmark — the paper's '600× over gem5' story, redone
for accelerators: one event-heap simulation vs the vectorised JAX kernel
batched over a whole design-space sweep (seeds × injection rates)."""
import time

import numpy as np

from repro.core import (build_tables, get_scheduler, make_soc_table2,
                        poisson_trace, simulate, simulate_batch, wifi_tx)

NUM_JOBS = 80
BATCH = 64          # design points evaluated at once by the JAX kernel


def run():
    db = make_soc_table2()
    app = wifi_tx()
    traces = [poisson_trace(5.0 + 70.0 * i / BATCH, NUM_JOBS, ["wifi_tx"],
                            seed=i) for i in range(BATCH)]

    # reference event-heap kernel, one by one
    t0 = time.perf_counter()
    ref_lat = [simulate(db, [app], t, get_scheduler("etf")).avg_job_latency_us
               for t in traces]
    t_ref = time.perf_counter() - t0

    # vectorised kernel: one batched tensor program
    tables = build_tables(db, [app])
    arr = np.stack([t.arrival_us for t in traces])
    idx = np.stack([t.app_index for t in traces])
    out = simulate_batch(tables, "etf", arr, idx)        # includes jit compile
    out["avg_job_latency_us"].block_until_ready()
    t0 = time.perf_counter()
    out = simulate_batch(tables, "etf", arr, idx)
    out["avg_job_latency_us"].block_until_ready()
    t_jax = time.perf_counter() - t0

    agree = np.allclose(np.asarray(out["avg_job_latency_us"]),
                        np.asarray(ref_lat), rtol=1e-3)
    per_sim_ref = t_ref / BATCH * 1e6
    per_sim_jax = t_jax / BATCH * 1e6
    return [
        ("speedup/ref_kernel", per_sim_ref, "us_per_simulation"),
        ("speedup/jax_kernel_batched", per_sim_jax, "us_per_simulation"),
        ("speedup/jax_over_ref", per_sim_ref / per_sim_jax,
         f"x_speedup(batch={BATCH},agree={agree})"),
        ("speedup/events_per_sec",
         BATCH * NUM_JOBS * app.num_tasks / t_jax, "scheduled_tasks_per_s"),
    ]
