"""Simulation-speed benchmark — the paper's '600× over gem5' story, redone
for accelerators: the event-heap reference kernel one scenario at a time vs
the whole workload grid as ONE vmapped/jitted ``sweep`` (which also fuses
the RC thermal co-simulation)."""
from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

import numpy as np

from repro.obs import bench_cli, scaled, timer
from repro.scenario import Scenario, TraceSpec, run as run_scenario, sweep

NUM_JOBS = 80
BATCH = 64          # workload points evaluated at once by the JAX kernel

BASE = Scenario(apps=("wifi_tx",), scheduler="etf")


def run(smoke: bool = False):
    batch = scaled(BATCH, 8, smoke)
    num_jobs = scaled(NUM_JOBS, 16, smoke)
    specs = [TraceSpec(rate_jobs_per_ms=5.0 + 70.0 * i / batch,
                       num_jobs=num_jobs, seed=i) for i in range(batch)]
    # traces materialised once, outside every timed region
    traces = [ts.materialize(BASE.app_names()) for ts in specs]

    # reference event-heap kernel, one scenario at a time
    t_ref = timer("bench.speedup.ref")
    with t_ref:
        ref_lat = [run_scenario(BASE.replace(trace=ts), backend="ref",
                                trace_override=tr).avg_latency_us
                   for ts, tr in zip(specs, traces)]

    # vectorised kernel: the full trace axis in one batched tensor program
    sr = sweep(BASE, axes={"trace": traces})         # includes jit compile
    t_jax = timer("bench.speedup.jax_warm")
    with t_jax:
        sr = sweep(BASE, axes={"trace": traces})

    agree = np.allclose(sr.avg_latency_us, np.asarray(ref_lat), rtol=1e-3)
    num_tasks = BASE.applications()[0].num_tasks
    per_sim_ref = t_ref.last_s / batch * 1e6
    per_sim_jax = t_jax.last_s / batch * 1e6
    return [
        ("speedup/ref_kernel", per_sim_ref, "us_per_simulation"),
        ("speedup/jax_kernel_batched", per_sim_jax,
         "us_per_simulation_incl_thermal"),
        ("speedup/jax_over_ref", per_sim_ref / per_sim_jax,
         f"x_speedup(batch={batch},agree={agree})"),
        ("speedup/events_per_sec",
         batch * num_jobs * num_tasks / t_jax.last_s, "scheduled_tasks_per_s"),
    ]


def main(argv=None) -> int:
    return bench_cli(run, "speedup", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
