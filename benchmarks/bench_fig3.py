"""Paper Figure 3: average job execution time vs injection rate for
MET / ETF / ILP-table schedulers on the Table-2 SoC (WiFi-TX workload).

All work is declared through one ``Scenario``; the rate × seed grid per
scheduler is a single ``sweep(..., backend="ref")``.
"""
from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

from repro.obs import bench_cli, scaled, timer
from repro.scenario import Scenario, TraceSpec, sweep

RATES = [1, 5, 10, 20, 30, 40, 50, 60, 70, 80]
NUM_JOBS = 120
SEEDS = (0, 1, 2)


def run(smoke: bool = False):
    rates = scaled(RATES, [1, 20, 80], smoke)
    seeds = scaled(SEEDS, (0,), smoke)
    base = Scenario(apps=("wifi_tx",),
                    trace=TraceSpec(num_jobs=scaled(NUM_JOBS, 24, smoke)))
    rows = []
    curves = {}
    t = timer("bench.fig3.sweep")
    for name, policy in [("met", "met"), ("etf", "etf"), ("ilp", "table")]:
        scn = base.replace(scheduler=policy)
        with t:
            sr = sweep(scn, axes={"rate": rates, "seed": seeds}, backend="ref")
        dt = t.last_us / (len(rates) * len(seeds))
        ys = [float(v) for v in sr.avg_latency_us.mean(axis=1)]
        curves[name] = ys
        for rate, y in zip(rates, ys):
            rows.append((f"fig3/{name}/rate{rate}", y, "avg_job_latency_us"))
        rows.append((f"fig3/{name}/sim_cost", dt, "us_per_simulation"))
    # the paper's qualitative claims, as derived checks
    lo, hi = 0, len(rates) - 1
    rows.append(("fig3/check_low_rate_similar",
                 max(curves[n][lo] for n in curves)
                 / min(curves[n][lo] for n in curves),
                 "max/min<1.15"))
    rows.append(("fig3/check_high_rate_order",
                 float(curves["etf"][hi] < curves["ilp"][hi] < curves["met"][hi]),
                 "etf<ilp<met"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "fig3", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
