"""Paper Figure 3: average job execution time vs injection rate for
MET / ETF / ILP-table schedulers on the Table-2 SoC (WiFi-TX workload)."""
import time

import numpy as np

from repro.core import (TableScheduler, get_scheduler, make_soc_table2,
                        poisson_trace, simulate, solve_optimal_table, wifi_tx)

RATES = [1, 5, 10, 20, 30, 40, 50, 60, 70, 80]
NUM_JOBS = 120
SEEDS = (0, 1, 2)


def run():
    db = make_soc_table2()
    app = wifi_tx()
    table = solve_optimal_table(db, app)
    rows = []
    curves = {}
    for name, mk in [("met", lambda: get_scheduler("met")),
                     ("etf", lambda: get_scheduler("etf")),
                     ("ilp", lambda: TableScheduler(table))]:
        t0 = time.perf_counter()
        ys = []
        for rate in RATES:
            vals = [simulate(db, [app],
                             poisson_trace(rate, NUM_JOBS, ["wifi_tx"], seed=s),
                             mk()).avg_job_latency_us for s in SEEDS]
            ys.append(float(np.mean(vals)))
        dt = (time.perf_counter() - t0) * 1e6 / (len(RATES) * len(SEEDS))
        curves[name] = ys
        for rate, y in zip(RATES, ys):
            rows.append((f"fig3/{name}/rate{rate}", y, "avg_job_latency_us"))
        rows.append((f"fig3/{name}/sim_cost", dt, "us_per_simulation"))
    # the paper's qualitative claims, as derived checks
    lo, hi = 0, len(RATES) - 1
    rows.append(("fig3/check_low_rate_similar",
                 max(curves[n][lo] for n in curves)
                 / min(curves[n][lo] for n in curves),
                 "max/min<1.15"))
    rows.append(("fig3/check_high_rate_order",
                 float(curves["etf"][hi] < curves["ilp"][hi] < curves["met"][hi]),
                 "etf<ilp<met"))
    return rows
