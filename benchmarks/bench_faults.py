"""Fail-stop fault lanes (DESIGN.md §14): the cost of resilience sweeps.

One design/trace grid run three ways — fault-free baseline, a k-PE-loss
fault lane axis vmapped through the fail-stop kernel, and the same faulted
grid streamed in chunks — so every PR benchmarks (a) the faulted kernel's
warm throughput against the fault-free program it extends, (b) the no-op
contract (an all-``inf`` fault axis reuses the fault-free program, zero
extra compiles — the CC001 gate reads the counters emitted here), and
(c) the degraded-mode makespan spread the resilience metric ranks designs
by.

``python -m benchmarks.bench_faults [--smoke] [--json PATH]`` runs this
module alone and optionally dumps the rows + run manifest as JSON (the CI
perf artifact).
"""
from __future__ import annotations

from ._devices import apply_devices_flag

apply_devices_flag()  # --devices N: sets XLA_FLAGS before the first jax use

import numpy as np

from repro.obs import bench_cli, scaled, timer
from repro.scenario import FaultSpec, Scenario, TraceSpec, pe_loss_faults, sweep

POLICY = "etf"
NUM_SEEDS = 4
NUM_JOBS = 64
FAULT_TIME_US = 400.0


def run(smoke: bool = False):
    seeds = tuple(range(scaled(NUM_SEEDS, 2, smoke)))
    num_jobs = scaled(NUM_JOBS, 16, smoke)
    scn = Scenario(apps=("wifi_tx",), scheduler=POLICY,
                   trace=TraceSpec(rate_jobs_per_ms=20.0,
                                   num_jobs=num_jobs))
    num_pes = scn.design.num_pes
    # every 1-PE loss plus the fault-free lane: F = P + 1 lanes
    lanes = ((),) + pe_loss_faults(range(num_pes),
                                   fail_time_us=FAULT_TIME_US, k=1)
    noop_lanes = [(), (FaultSpec(0, float("inf")),)]

    rows = []
    results = {}
    for mode, axes, kw in [
            ("free", {"seed": seeds}, {}),
            ("faulted", {"faults": list(lanes), "seed": seeds}, {}),
            ("faulted_chunked", {"faults": list(lanes), "seed": seeds},
             dict(chunk=1)),
            ("noop_axis", {"faults": noop_lanes, "seed": seeds}, {})]:
        t = timer(f"bench.faults.{mode}")
        with t:                                   # cold: includes compile
            results[mode] = sweep(scn, axes=axes, **kw)
        cold_us = t.last_us
        with t:                                   # warm: cached program
            results[mode] = sweep(scn, axes=axes, **kw)
        n_sims = int(np.prod(results[mode].makespan_us.shape))
        rows.append((f"faults/{mode}_warm_us", t.last_us,
                     f"cold={cold_us:.0f}us"))
        rows.append((f"faults/{mode}_lanes_per_s",
                     n_sims / max(t.last_s, 1e-9), f"{n_sims}sims"))

    free = timer("bench.faults.free").last_s
    faulted = timer("bench.faults.faulted").last_s
    rows.append(("faults/overhead_x",
                 faulted / max(free, 1e-9),
                 f"{len(lanes)}x lanes warm faulted-vs-free"))
    # per-simulation slowdown of the fail-stop scan (longer static bound)
    per_sim = (faulted / len(lanes)) / max(free, 1e-9)
    rows.append(("faults/per_sim_overhead_x", per_sim,
                 "amortised per fault lane"))

    mk = results["faulted"].makespan_us            # (F, S)
    degraded = mk[1:].mean(axis=1)                 # per lost PE
    nominal = float(mk[0].mean())
    rows.append(("faults/nominal_makespan_us", nominal, "fault-free lane"))
    rows.append(("faults/worst_loss_makespan_us", float(degraded.max()),
                 f"worst single-PE loss @t={FAULT_TIME_US:.0f}us"))
    rows.append(("faults/degradation_x", float(degraded.max()) / nominal,
                 "worst-loss / nominal"))
    noop = results["noop_axis"].makespan_us
    rows.append(("faults/noop_bitexact",
                 float(np.array_equal(noop[0], noop[1])
                       and np.array_equal(
                           noop[0], results["free"].makespan_us)),
                 "1.0=no-op lanes equal fault-free"))
    rows.append(("faults/chunked_bitexact",
                 float(np.array_equal(results["faulted"].makespan_us,
                                      results["faulted_chunked"]
                                      .makespan_us)),
                 "1.0=chunked faulted grid equals unchunked"))
    return rows


def main(argv=None) -> int:
    return bench_cli(run, "faults", __doc__, argv)


if __name__ == "__main__":
    raise SystemExit(main())
