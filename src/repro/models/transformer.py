"""Block assembly: pattern-cycled layer stacks with scan-over-superblocks.

A config's ``block_pattern`` (e.g. ``("rglru","rglru","local")``) is cycled
over ``num_layers``.  Parameters for each pattern position are *stacked* along
a leading repeat axis and the stack runs under ``lax.scan`` (one superblock in
the HLO regardless of depth — compile time stays flat at 1000-node scale);
the non-divisible remainder runs unrolled as a tail.  ``cfg.scan_layers=False``
unrolls everything (perf lever: enables cross-layer fusion, grows HLO).

Block types: global | local | rglru | mamba2 | enc | xdec.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from . import attention as attn
from . import griffin, moe as moe_mod, ssm
from .layers import apply_mlp, apply_rmsnorm, init_mlp, init_rmsnorm
from .params import ParamStore


def pattern_of(cfg: ModelConfig, encoder: bool = False) -> Tuple[str, ...]:
    if encoder:
        return ("enc",)
    if cfg.is_encoder_decoder:
        return ("xdec",)
    return cfg.block_pattern


def stack_layout(cfg: ModelConfig, encoder: bool = False) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(pattern, repeats, tail_block_types)."""
    pat = pattern_of(cfg, encoder)
    n = cfg.num_encoder_layers if encoder else cfg.num_layers
    reps = n // len(pat)
    tail = pat[: n % len(pat)]
    return pat, reps, tail


def _is_moe(cfg: ModelConfig) -> bool:
    return cfg.num_experts > 0


# ---------------------------------------------------------------- block init

def init_block(ps: ParamStore, path: str, cfg: ModelConfig, btype: str,
               stacked: Optional[int]):
    D = cfg.d_model
    if btype in ("global", "local", "enc", "xdec"):
        init_rmsnorm(ps, f"{path}/norm1", D, stacked)
        attn.init_attention(ps, f"{path}/attn", cfg, stacked)
        if btype == "xdec":
            init_rmsnorm(ps, f"{path}/normx", D, stacked)
            attn.init_attention(ps, f"{path}/xattn", cfg, stacked)
        init_rmsnorm(ps, f"{path}/norm2", D, stacked)
        if _is_moe(cfg):
            moe_mod.init_moe(ps, f"{path}/moe", cfg, stacked)
        else:
            init_mlp(ps, f"{path}/mlp", cfg, cfg.d_ff, stacked)
    elif btype == "rglru":
        init_rmsnorm(ps, f"{path}/norm1", D, stacked)
        griffin.init_griffin(ps, f"{path}/rec", cfg, stacked)
        init_rmsnorm(ps, f"{path}/norm2", D, stacked)
        init_mlp(ps, f"{path}/mlp", cfg, cfg.d_ff, stacked)
    elif btype == "mamba2":
        init_rmsnorm(ps, f"{path}/norm1", D, stacked)
        ssm.init_mamba(ps, f"{path}/mamba", cfg, stacked)
    else:
        raise ValueError(f"unknown block type {btype!r}")


def init_stack(ps: ParamStore, path: str, cfg: ModelConfig,
               encoder: bool = False):
    pat, reps, tail = stack_layout(cfg, encoder)
    for i, bt in enumerate(pat):
        init_block(ps, f"{path}/stack/p{i}", cfg, bt, stacked=reps)
    for j, bt in enumerate(tail):
        init_block(ps, f"{path}/tail/t{j}", cfg, bt, stacked=None)


# ---------------------------------------------------------------- block apply

def _ffn(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = apply_rmsnorm(p["norm2"], x, cfg.norm_eps)
    if _is_moe(cfg):
        y = moe_mod.apply_moe(p["moe"], cfg, h, impl=cfg.moe_impl,
                              group_size=cfg.moe_group_size)
    else:
        y = apply_mlp(p["mlp"], cfg, h)
    return x + y


def apply_block(p, cfg: ModelConfig, btype: str, x: jax.Array,
                positions: jax.Array, enc_out: Optional[jax.Array] = None):
    """Training forward for one block."""
    if btype in ("global", "local", "enc", "xdec"):
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        window = cfg.window_size if btype == "local" else None
        causal = btype != "enc"
        y = attn.self_attention(p["attn"], cfg, h, positions, window,
                                causal=causal)
        x = x + y
        if btype == "xdec":
            h = apply_rmsnorm(p["normx"], x, cfg.norm_eps)
            kv = attn.encode_cross_kv(p["xattn"], cfg, enc_out)
            x = x + attn.cross_attention(p["xattn"], cfg, h, kv)
        x = _ffn(p, cfg, x)
    elif btype == "rglru":
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + griffin.apply_griffin(p["rec"], cfg, h)
        x = _ffn(p, cfg, x)
    elif btype == "mamba2":
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + ssm.apply_mamba(p["mamba"], cfg, h)
    else:
        raise ValueError(btype)
    return shard(x, "batch", None, None)


# ---------------------------------------------------------------- cache

def init_block_cache(cfg: ModelConfig, btype: str, batch: int, max_len: int,
                     enc_len: int = 0, abstract: bool = False) -> Dict:
    if btype in ("global", "xdec"):
        c = {"kv": attn.init_cache(cfg, batch, max_len, None, abstract)}
        if btype == "xdec":
            shape = (batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
            dt = jnp.dtype(cfg.dtype)
            mk = (lambda: jax.ShapeDtypeStruct(shape, dt)) if abstract \
                else (lambda: jnp.zeros(shape, dt))
            c["ck"], c["cv"] = mk(), mk()
        return c
    if btype == "local":
        return {"kv": attn.init_cache(cfg, batch, max_len, cfg.window_size,
                                      abstract)}
    if btype == "rglru":
        return {"rec": griffin.init_griffin_cache(cfg, batch, abstract)}
    if btype == "mamba2":
        return {"ssm": ssm.init_mamba_cache(cfg, batch, abstract)}
    raise ValueError(btype)


def prefill_block(p, cfg: ModelConfig, btype: str, x: jax.Array,
                  positions: jax.Array, max_len: int,
                  enc_out: Optional[jax.Array] = None):
    """Forward + cache construction (serving prefill)."""
    if btype in ("global", "local", "xdec"):
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        window = cfg.window_size if btype == "local" else None
        y, (k, v) = attn.self_attention(p["attn"], cfg, h, positions, window,
                                        causal=True, return_kv=True)
        x = x + y
        cache = {"kv": attn.build_cache_from_prefill(cfg, k, v, max_len, window)}
        if btype == "xdec":
            h = apply_rmsnorm(p["normx"], x, cfg.norm_eps)
            ck, cv = attn.encode_cross_kv(p["xattn"], cfg, enc_out)
            x = x + attn.cross_attention(p["xattn"], cfg, h, (ck, cv))
            cache["ck"], cache["cv"] = ck, cv
        x = _ffn(p, cfg, x)
    elif btype == "rglru":
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, rec_cache = griffin.apply_griffin(p["rec"], cfg, h, return_cache=True)
        x = x + y
        x = _ffn(p, cfg, x)
        cache = {"rec": rec_cache}
    elif btype == "mamba2":
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, mcache = ssm.apply_mamba(p["mamba"], cfg, h, return_cache=True)
        x = x + y
        cache = {"ssm": mcache}
    else:
        raise ValueError(btype)
    return shard(x, "batch", None, None), cache


def decode_block(p, cfg: ModelConfig, btype: str, x: jax.Array, cache: Dict,
                 pos: jax.Array):
    if btype in ("global", "local", "xdec"):
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        window = cfg.window_size if btype == "local" else None
        y, kv = attn.decode_self_attention(p["attn"], cfg, h, cache["kv"],
                                           pos, window)
        x = x + y
        new_cache = {"kv": kv}
        if btype == "xdec":
            h = apply_rmsnorm(p["normx"], x, cfg.norm_eps)
            x = x + attn.cross_attention(p["xattn"], cfg, h,
                                         (cache["ck"], cache["cv"]))
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
        x = _ffn(p, cfg, x)
    elif btype == "rglru":
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, rec = griffin.decode_griffin(p["rec"], cfg, h, cache["rec"])
        x = x + y
        x = _ffn(p, cfg, x)
        new_cache = {"rec": rec}
    elif btype == "mamba2":
        h = apply_rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, mc = ssm.decode_mamba(p["mamba"], cfg, h, cache["ssm"])
        x = x + y
        new_cache = {"ssm": mc}
    else:
        raise ValueError(btype)
    return x, new_cache


# ---------------------------------------------------------------- stacks

def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def apply_stack(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                encoder: bool = False, enc_out: Optional[jax.Array] = None):
    """Training forward through the whole stack."""
    pat, reps, tail = stack_layout(cfg, encoder)

    def one_repeat(x, psl):
        for i, bt in enumerate(pat):
            x = apply_block(psl[f"p{i}"], cfg, bt, x, positions, enc_out)
        return x

    body = _remat_wrap(cfg, one_repeat)
    if reps:
        if cfg.pipeline_stages > 1 and not encoder:
            assert not tail, "pipeline mode: layers % pattern must be 0"
            from .pipeline import pipeline_stack
            x = pipeline_stack(params["stack"], cfg, x, positions, body,
                               cfg.pipeline_microbatches)
        elif cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, s: (body(c, s), None),
                                x, params["stack"])
        else:
            for r in range(reps):
                x = body(x, jax.tree.map(lambda a: a[r], params["stack"]))
    for j, bt in enumerate(tail):
        x = apply_block(params["tail"][f"t{j}"], cfg, bt, x, positions, enc_out)
    return x


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                     enc_len: int = 0, abstract: bool = False) -> Dict:
    pat, reps, tail = stack_layout(cfg)
    out: Dict[str, Any] = {"stack": {}, "tail": {}}
    for i, bt in enumerate(pat):
        one = init_block_cache(cfg, bt, batch, max_len, enc_len, abstract)
        if abstract:
            out["stack"][f"p{i}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), one)
        else:
            out["stack"][f"p{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), one)
    for j, bt in enumerate(tail):
        out["tail"][f"t{j}"] = init_block_cache(cfg, bt, batch, max_len,
                                                enc_len, abstract)
    return out


def prefill_stack(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                  max_len: int, enc_out: Optional[jax.Array] = None):
    pat, reps, tail = stack_layout(cfg)

    def one_repeat(x, psl):
        caches = {}
        for i, bt in enumerate(pat):
            x, c = prefill_block(psl[f"p{i}"], cfg, bt, x, positions, max_len,
                                 enc_out)
            caches[f"p{i}"] = c
        return x, caches

    cache: Dict[str, Any] = {"stack": {}, "tail": {}}
    if reps:
        if cfg.scan_layers:
            x, cache["stack"] = jax.lax.scan(one_repeat, x, params["stack"])
        else:
            slices = []
            for r in range(reps):
                x, c = one_repeat(x, jax.tree.map(lambda a: a[r],
                                                  params["stack"]))
                slices.append(c)
            cache["stack"] = jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
    for j, bt in enumerate(tail):
        x, c = prefill_block(params["tail"][f"t{j}"], cfg, bt, x, positions,
                             max_len, enc_out)
        cache["tail"][f"t{j}"] = c
    return x, cache


def decode_stack(params, cfg: ModelConfig, x: jax.Array, cache: Dict,
                 pos: jax.Array):
    """One decode step through the stack.

    The KV/state cache is threaded as scan CARRY (not xs/ys) and updated with
    ``dynamic_update_index_in_dim`` — the while-loop carry keeps one buffer,
    so with donation the multi-GB cache updates in place instead of being
    copied through a ys output (2× cache temp otherwise; measured on
    gemma2-2b decode_32k)."""
    pat, reps, tail = stack_layout(cfg)

    def one_repeat(x, cstack, psl, r):
        for i, bt in enumerate(pat):
            csl = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, r, 0, keepdims=False),
                cstack[f"p{i}"])
            x, c = decode_block(psl[f"p{i}"], cfg, bt, x, csl, pos)
            cstack = dict(cstack)
            cstack[f"p{i}"] = jax.tree.map(
                lambda buf, upd: jax.lax.dynamic_update_index_in_dim(
                    buf, upd, r, 0), cstack[f"p{i}"], c)
        return x, cstack

    new_cache: Dict[str, Any] = {"stack": {}, "tail": {}}
    if reps:
        if cfg.scan_layers:
            def body(carry, inp):
                x, cstack = carry
                psl, r = inp
                x, cstack = one_repeat(x, cstack, psl, r)
                return (x, cstack), None

            (x, new_cache["stack"]), _ = jax.lax.scan(
                body, (x, cache["stack"]),
                (params["stack"], jnp.arange(reps, dtype=jnp.int32)))
        else:
            cstack = cache["stack"]
            for r in range(reps):
                x, cstack = one_repeat(
                    x, cstack, jax.tree.map(lambda a: a[r], params["stack"]),
                    jnp.int32(r))
            new_cache["stack"] = cstack
    for j, bt in enumerate(tail):
        x, c = decode_block(params["tail"][f"t{j}"], cfg, bt, x,
                            cache["tail"][f"t{j}"], pos)
        new_cache["tail"][f"t{j}"] = c
    return x, new_cache
