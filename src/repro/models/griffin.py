"""Griffin recurrent block (RecurrentGemma): conv1d + RG-LRU gated recurrence.

    y = W_out( GeLU(W_gate·x) ⊙ RG-LRU(conv1d(W_x·x)) )

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = σ(blockdiag(W_a)·u_t + b_a)          recurrence gate
    i_t = σ(blockdiag(W_i)·u_t + b_i)          input gate
    log a_t = -c · softplus(Λ) · r_t           (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ u_t)

Train/prefill evaluates the recurrence with ``jax.lax.associative_scan``
(XLA path) or the ``repro.kernels.rg_lru`` sequential-in-VMEM TPU kernel.
Decode is the O(1) recurrent step.  Constant-size state ⇒ this family runs
the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .params import ParamStore

RG_LRU_C = 8.0


def init_griffin(ps: ParamStore, path: str, cfg: ModelConfig,
                 stacked: Optional[int]):
    D, W = cfg.d_model, cfg.lru_width
    H = cfg.num_heads                         # gate blocks
    bw = W // H
    pre = (stacked,) if stacked else ()
    pax = (None,) if stacked else ()
    ps.param(f"{path}/w_x", pre + (D, W), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/w_gate", pre + (D, W), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/conv_w", pre + (cfg.conv_width, W), pax + (None, "model"),
             "normal", scale=0.1)
    ps.param(f"{path}/conv_b", pre + (W,), pax + ("model",), "zeros")
    ps.param(f"{path}/wa", pre + (H, bw, bw), pax + (None, None, None), "fan_in")
    ps.param(f"{path}/ba", pre + (W,), pax + ("model",), "zeros", dtype=jnp.float32)
    ps.param(f"{path}/wi", pre + (H, bw, bw), pax + (None, None, None), "fan_in")
    ps.param(f"{path}/bi", pre + (W,), pax + ("model",), "zeros", dtype=jnp.float32)
    # Λ init so that a = exp(-c·softplus(Λ)) lands in (0.9, 0.999)
    ps.param(f"{path}/lam", pre + (W,), pax + ("model",), "normal", scale=0.5,
             dtype=jnp.float32)
    ps.param(f"{path}/w_out", pre + (W, D), pax + ("model", "fsdp"), "fan_in")


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, k:k + x.shape[1], :] * w[k].astype(x.dtype) for k in range(K))
    return y + b.astype(x.dtype)


def _block_linear(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Block-diagonal linear: u (...,W), w (H,bw,bw) -> (...,W)."""
    H, bw, _ = w.shape
    uh = u.reshape(*u.shape[:-1], H, bw)
    y = jnp.einsum("...hi,hij->...hj", uh, w.astype(u.dtype))
    return y.reshape(*u.shape) + b.astype(u.dtype)


def _gates(p, u: jax.Array):
    """Returns (log_a, gated_input) in f32; u: (..., W)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_linear(uf, p["wa"].astype(jnp.float32), p["ba"]))
    i = jax.nn.sigmoid(_block_linear(uf, p["wi"].astype(jnp.float32), p["bi"]))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r       # (..., W)  <= 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * i * uf


def rg_lru_scan(p, u: jax.Array, h0: Optional[jax.Array],
                use_kernel: bool = False):
    """u: (B,S,W) -> (h_all: (B,S,W) f32, h_last: (B,W) f32)."""
    log_a, x_in = _gates(p, u)                              # f32
    a = jnp.exp(log_a)
    if use_kernel:
        from ..kernels import ops as kops
        h = kops.rg_lru(a, x_in, h0)
    else:
        if h0 is not None:
            x_in = x_in.at[:, 0, :].add(a[:, 0, :] * h0)
        def op(ca, cb):
            a1, b1 = ca
            a2, b2 = cb
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(op, (a, x_in), axis=1)
    return h, h[:, -1, :]


def apply_griffin(p, cfg: ModelConfig, x: jax.Array,
                  return_cache: bool = False):
    """Train/prefill.  x: (B,S,D) -> (B,S,D) [+ decode cache]."""
    B, S, D = x.shape
    dt_ = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt_)))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(dt_))
    u = shard(u, "batch", None, "model")
    u_conv = _causal_conv(u, p["conv_w"], p["conv_b"])
    h, h_last = rg_lru_scan(p, u_conv, None,
                            use_kernel=(cfg.attn_impl == "pallas"))
    y = gate * h.astype(dt_)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dt_))
    out = shard(out, "batch", None, None)
    if not return_cache:
        return out
    K = cfg.conv_width
    return out, {"conv": u[:, S - (K - 1):, :], "h": h_last}


def init_griffin_cache(cfg: ModelConfig, batch: int, abstract: bool = False) -> Dict:
    W = cfg.lru_width
    dt = jnp.dtype(cfg.dtype)
    shapes = {"conv": ((batch, cfg.conv_width - 1, W), dt),
              "h": ((batch, W), jnp.float32)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def decode_griffin(p, cfg: ModelConfig, x: jax.Array, cache: Dict):
    """One-token step.  x: (B,1,D)."""
    B = x.shape[0]
    dt_ = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt_)))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(dt_))[:, 0]   # (B,W)
    hist = jnp.concatenate([cache["conv"], u[:, None, :]], axis=1)
    w = p["conv_w"].astype(dt_)
    u_conv = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(dt_)
    log_a, x_in = _gates(p, u_conv)
    h = jnp.exp(log_a) * cache["h"] + x_in                  # (B,W) f32
    y = gate[:, 0] * h.astype(dt_)
    out = jnp.einsum("bw,wd->bd", y, p["w_out"].astype(dt_))[:, None, :]
    return out, {"conv": hist[:, 1:, :], "h": h}
