"""Model substrate: layers, attention, MoE, SSM, Griffin, stacks, Model API."""
from .model import Model, build_model
from .params import ParamStore

__all__ = ["Model", "build_model", "ParamStore"]
