"""Model: the public composable API over all 10 architecture families.

    model = Model(get_config("gemma2-2b"))
    params = model.init_params(rng)          # or model.abstract_params()
    loss   = model.loss_fn(params, batch)    # train forward
    logits, cache = model.prefill(params, batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens, pos)

Batches are dicts:
    lm families:  {"tokens": (B,S) i32, "labels": (B,S) i32}
    vlm:          + {"patch_embeds": (B, n_prefix, D)}   (SigLIP stub)
    audio:        {"frames": (B, S_enc, D), "tokens", "labels"}  (enc-dec)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from . import transformer as tf
from .layers import (apply_rmsnorm, cross_entropy, dtype_of, embed_tokens,
                     init_embeddings, init_rmsnorm, lm_logits)
from .params import ParamStore


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def _init(self, ps: ParamStore):
        cfg = self.cfg
        init_embeddings(ps, cfg)
        if cfg.frontend == "vision":
            ps.param("frontend/proj", (cfg.d_model, cfg.d_model),
                     ("fsdp", None), "fan_in")
        elif cfg.frontend == "audio":
            ps.param("frontend/proj", (cfg.d_model, cfg.d_model),
                     ("fsdp", None), "fan_in")
        if cfg.is_encoder_decoder:
            tf.init_stack(ps, "encoder", cfg, encoder=True)
            init_rmsnorm(ps, "enc_norm", cfg.d_model, None)
        tf.init_stack(ps, "decoder", cfg)
        init_rmsnorm(ps, "final_norm", cfg.d_model, None)

    def init_params(self, rng: jax.Array):
        ps = ParamStore(rng, dtype_of(self.cfg), abstract=False)
        self._init(ps)
        self._specs, self._logical = ps.specs, ps.logical
        return ps.params

    def abstract_params(self):
        ps = ParamStore(None, dtype_of(self.cfg), abstract=True)
        self._init(ps)
        self._specs, self._logical = ps.specs, ps.logical
        return ps.params

    def param_pspecs(self):
        """PartitionSpec tree (valid under the currently-installed rules)."""
        self.abstract_params()
        return self._specs

    def param_logical(self):
        self.abstract_params()
        return self._logical

    def param_count(self) -> int:
        import math
        p = self.abstract_params()
        return sum(math.prod(l.shape) for l in jax.tree.leaves(p))

    # ------------------------------------------------------------- helpers
    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = embed_tokens(params, cfg, batch["tokens"])
        if cfg.frontend == "vision":
            pe = batch["patch_embeds"].astype(x.dtype)
            pe = jnp.einsum("bsd,de->bse", pe,
                            params["frontend"]["proj"].astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
        return shard(x, "batch", None, None)

    def _encode(self, params, batch) -> jax.Array:
        cfg = self.cfg
        fr = batch["frames"]
        enc_in = jnp.einsum("bsd,de->bse", fr.astype(dtype_of(cfg)),
                            params["frontend"]["proj"].astype(dtype_of(cfg)))
        pos = jnp.arange(enc_in.shape[1])[None, :]
        h = tf.apply_stack(params["encoder"], cfg, enc_in, pos, encoder=True)
        return apply_rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    # ------------------------------------------------------------- train
    def forward_logits(self, params, batch) -> jax.Array:
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.is_encoder_decoder else None
        x = self._embed_inputs(params, batch)
        pos = jnp.arange(x.shape[1])[None, :]
        x = tf.apply_stack(params["decoder"], cfg, x, pos, enc_out=enc_out)
        x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.frontend == "vision":          # logits over text positions only
            n = cfg.num_prefix_tokens
            x = x[:, n:, :]
        return lm_logits(params, cfg, x)

    def loss_fn(self, params, batch) -> jax.Array:
        logits = self.forward_logits(params, batch)
        return cross_entropy(logits, batch["labels"])

    # ------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int, enc_len: int = 0,
                   abstract: bool = False):
        return tf.init_stack_cache(self.cfg, batch, max_len, enc_len, abstract)

    def prefill(self, params, batch, max_len: int):
        """Returns (last-position logits, cache ready for decode)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.is_encoder_decoder else None
        x = self._embed_inputs(params, batch)
        pos = jnp.arange(x.shape[1])[None, :]
        x, cache = tf.prefill_stack(params["decoder"], cfg, x, pos, max_len,
                                    enc_out=enc_out)
        x = apply_rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
        return lm_logits(params, cfg, x), cache

    def decode_step(self, params, cache, tokens: jax.Array, pos: jax.Array):
        """tokens: (B,1) i32; pos: scalar i32 position of the new token."""
        cfg = self.cfg
        x = embed_tokens(params, cfg, tokens)
        x, cache = tf.decode_stack(params["decoder"], cfg, x, cache, pos)
        x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return lm_logits(params, cfg, x), cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
