"""Attention: MHA/GQA/MQA with RoPE, causal/sliding-window masks, softcap,
KV caches (full or ring-buffer for local layers), cross-attention.

Projections are stored *flattened* — wq: (D, H·Dh), wk/wv: (D, KV·Dh),
wo: (H·Dh, D) — so the tensor-parallel shard axis is the fused head dim,
which is divisible by the 16-way ``model`` axis for every assigned arch
(raw head counts like 36 or 10 are not).  Heads are reshaped locally.

Two execution paths:
  * ``einsum`` — reference XLA path (smoke tests AND the dry-run, so
    ``cost_analysis`` sees explicit FLOPs/bytes);
  * ``pallas`` — TPU flash kernels from ``repro.kernels`` (tiled, O(S)
    memory), validated against this path in interpret mode.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from functools import partial

from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map                      # jax >= 0.8
except ImportError:                                # pragma: no cover
    from jax.experimental.shard_map import shard_map

from ..configs.base import ModelConfig
from ..sharding import current_mesh, logical_to_pspec, mesh_axis, shard
from .layers import apply_rope
from .params import ParamStore


def shard_seq(x: jax.Array, dim: int = 1) -> jax.Array:
    """Sequence-shard an activation over the model axis when divisible.

    Keeps the huge RoPE / attention intermediates distributed: without this,
    (B,S,H,Dh) f32 temporaries replicate over the 16-way model axis (head
    counts like 36/10/8 are not divisible by it; the sequence always is)."""
    _, size = mesh_axis("q_seq")
    if size > 1 and x.shape[dim] % size == 0 and x.shape[dim] > 1:
        axes = [None] * x.ndim
        axes[0] = "batch"
        axes[dim] = "q_seq"
        return shard(x, *axes)
    return x


def init_attention(ps: ParamStore, path: str, cfg: ModelConfig,
                   stacked: Optional[int]):
    D, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pre = (stacked,) if stacked else ()
    pax = (None,) if stacked else ()
    ps.param(f"{path}/wq", pre + (D, H * Dh), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/wk", pre + (D, KV * Dh), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/wv", pre + (D, KV * Dh), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/wo", pre + (H * Dh, D), pax + ("model", "fsdp"), "fan_in")


def _proj(x: jax.Array, w: jax.Array, heads: int, head_dim: int) -> jax.Array:
    y = jnp.einsum("bsd,dm->bsm", x, w.astype(x.dtype))
    y = shard(y, "batch", None, "model")
    return y.reshape(*y.shape[:-1], heads, head_dim)


def _unproj(y: jax.Array, w: jax.Array, dtype) -> jax.Array:
    yf = y.reshape(*y.shape[:-2], -1)
    yf = shard(yf, "batch", None, "model")
    return jnp.einsum("bsm,md->bsd", yf, w.astype(dtype))


def _attend_einsum(q, k, v, mask, softcap, scale):
    """Grouped-query attention without materialising repeated KV.

    q: (B,Sq,H,Dh); k,v: (B,Sk,KV,Dh); H = KV·groups.
    mask: (1|B, 1, Sq, Sk) or None.  Returns (B,Sq,H,Dh).
    """
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * scale
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if mask is not None:
        logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, Dh)


def _attend_blocked(q, k, v, *, causal: bool, window: Optional[int],
                    softcap: Optional[float], scale: float,
                    chunk: int = 1024, scores_f32: bool = True):
    """Flash-style blocked attention in pure XLA.

    Why this exists (measured, see EXPERIMENTS.md §Perf iteration 1):
    * the naive einsum path materialises (Sq × Sk) logits — 159 GB/device on
      starcoder2-7b prefill_32k;
    * GQA head counts (36, 10, 8...) don't divide the 16-way ``model`` axis,
      so XLA replicates attention over it.  Here each q chunk is sharded on
      its SEQUENCE dim over ``model`` (context parallelism): divisible for
      every arch, balanced for causal masks (all shards share the k range).
    * causal/window chunks slice exactly the valid k range — no online
      softmax needed, out-of-window blocks never computed;
    * each chunk is wrapped in ``jax.checkpoint`` so backward recomputes it
      instead of saving per-chunk probabilities (Σ chunks = full S² again).
    """
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, Dh)
    chunk = min(chunk, Sq)
    outs = []
    for i0 in range(0, Sq, chunk):
        i1 = min(i0 + chunk, Sq)
        k_hi = min(i1, Sk) if causal else Sk
        k_lo = 0
        if window is not None:
            k_lo = max(0, ((i0 - window + 1) // 128) * 128)

        def do_chunk(qs, ks, vs, i0=i0, i1=i1, k_lo=k_lo, k_hi=k_hi):
            s = jnp.einsum("bqkgd,bskd->bkgqs", qs, ks) * scale
            s = s.astype(jnp.float32 if scores_f32 else qs.dtype)
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            rows = i0 + jnp.arange(i1 - i0)[:, None]
            cols = k_lo + jnp.arange(k_hi - k_lo)[None, :]
            m = jnp.ones((i1 - i0, k_hi - k_lo), bool)
            if causal:
                m &= cols <= rows
            if window is not None:
                m &= cols > rows - window
            s = jnp.where(m[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(qs.dtype)
            return jnp.einsum("bkgqs,bskd->bqkgd", p, vs)

        qs = shard(qg[:, i0:i1], "batch", "q_seq", None, None, None)
        o = jax.checkpoint(do_chunk)(qs, k[:, k_lo:k_hi], v[:, k_lo:k_hi])
        outs.append(shard(o, "batch", "q_seq", None, None, None))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    return out.reshape(B, Sq, H, Dh)


def _in_manual_region() -> bool:
    """True inside a partial-manual shard_map (e.g. pipeline 'pod' stages)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        return am is not None and bool(am.shape) and any(
            t == jax.sharding.AxisType.Manual for t in am.axis_types)
    except Exception:       # pragma: no cover
        return False


def _attend_cp(q, k, v, *, causal: bool, softcap: Optional[float],
               scale: float, chunk: int = 512, unroll: bool = False,
               scores_f32: bool = True):
    """Context-parallel attention via ``shard_map`` (global/unbounded layers).

    q is sequence-sharded over the model axis; each device holds S/16 query
    rows and streams them in serial chunks against the full K/V (explicit
    all-gather at the shard_map boundary — it shows up in the collective
    roofline term, ~2·S·KV·Dh bytes/layer).  Per-chunk working set is
    (B_loc · H · chunk · S) f32 — the serial python loop bounds live memory,
    which one fused einsum over all local rows would not.

    Causal masking is applied against full K (no early-exit): ~2× the
    minimal causal FLOPs, same as any masked-dense formulation; the Pallas
    kernel path removes that factor on real TPUs.
    """
    mesh = current_mesh()
    seq_axes, n_seq = mesh_axis("q_seq")
    B, Sq, H, Dh = q.shape
    if mesh is None or n_seq <= 1 or Sq % n_seq or Sq == 1 \
            or _in_manual_region():
        # _in_manual_region: nested manual computations over different axes
        # are not supported (pipeline stages bind 'pod'); use plain blocks
        return _attend_blocked(q, k, v, causal=causal, window=None,
                               softcap=softcap, scale=scale, chunk=chunk,
                               scores_f32=scores_f32)
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    S_loc = Sq // n_seq
    seq_ax = seq_axes[0]
    # bound one chunk's f32 score tensor to ~1 GB of live memory per device
    # (several layout copies of it coexist in the fused HLO)
    _, n_batch = mesh_axis("batch")
    b_loc = max(1, B // max(n_batch, 1))
    budget = int(1e9)
    max_chunk = max(64, budget // max(b_loc * H * Sk * 4, 1))
    chunk = min(chunk, 1 << (max_chunk.bit_length() - 1))
    bspec = logical_to_pspec(["batch"])         # batch mesh axes
    bax = bspec[0] if len(bspec) else None

    chunk = min(chunk, S_loc)
    while S_loc % chunk:
        chunk //= 2
    nc = S_loc // chunk

    def body(q_loc, k_f, v_f):
        midx = jax.lax.axis_index(seq_ax)
        row0 = midx * S_loc
        bl = q_loc.shape[0]
        qg = q_loc.reshape(bl, S_loc, KV, g, Dh)

        def do_chunk(qs, rows):
            s = jnp.einsum("bqkgd,bskd->bkgqs", qs, k_f) * scale
            s = s.astype(jnp.float32 if scores_f32 else qs.dtype)
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            if causal:
                cols = jnp.arange(Sk)[None, :]
                s = jnp.where(cols <= rows[:, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(qs.dtype)
            return jnp.einsum("bkgqs,bskd->bqkgd", p, v_f)

        if unroll:
            # probe path: unrolled chunks => cost_analysis counts every one
            outs = []
            for ci in range(nc):
                rows = row0 + ci * chunk + jnp.arange(chunk)
                outs.append(jax.checkpoint(do_chunk)(
                    qg[:, ci * chunk:(ci + 1) * chunk], rows))
            out = outs[0] if nc == 1 else jnp.concatenate(outs, axis=1)
        else:
            # production path: lax.scan serialises chunks — one chunk's f32
            # scores live at a time (the unrolled form peaked at ~40 GB on
            # starcoder2 prefill_32k: XLA:CPU keeps all chunk buffers live)
            xs = qg.reshape(bl, nc, chunk, KV, g, Dh).transpose(
                1, 0, 2, 3, 4, 5)

            def sbody(_, inp):
                qs, ci = inp
                rows = row0 + ci * chunk + jnp.arange(chunk)
                return None, jax.checkpoint(do_chunk)(qs, rows)

            _, os_ = jax.lax.scan(sbody, None,
                                  (xs, jnp.arange(nc, dtype=jnp.int32)))
            out = os_.transpose(1, 0, 2, 3, 4, 5).reshape(
                bl, S_loc, KV, g, Dh)
        return out.reshape(bl, S_loc, H, Dh)

    # manual ONLY over the sequence axis: batch/model stay auto, so this
    # composes under an outer (pipeline) shard_map that has 'pod' manual
    mesh_arg = mesh
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.shape and any(
                t == jax.sharding.AxisType.Manual for t in am.axis_types):
            mesh_arg = am
    except Exception:       # pragma: no cover — older jax
        pass
    kw = dict(mesh=mesh_arg,
              in_specs=(P(None, seq_ax, None, None), P(None, None, None, None),
                        P(None, None, None, None)),
              out_specs=P(None, seq_ax, None, None),
              axis_names={seq_ax})
    try:
        fn = shard_map(body, check_vma=False, **kw)      # jax >= 0.8
    except TypeError:                                    # pragma: no cover
        fn = shard_map(body, check_rep=False, **kw)
    return fn(q, k, v)


def make_causal_mask(sq: int, sk: int, q_offset, window: Optional[int]):
    """(1,1,Sq,Sk) bool; window=None => full causal, else sliding window."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


def self_attention(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                   window: Optional[int], causal: bool = True,
                   return_kv: bool = False):
    """Training/prefill self-attention over the whole (possibly windowed) seq."""
    B, S, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    groups = H // KV
    q = shard_seq(_proj(x, p["wq"], H, Dh))
    k = shard_seq(_proj(x, p["wk"], KV, Dh))
    v = shard_seq(_proj(x, p["wv"], KV, Dh))
    q = shard_seq(apply_rope(q, positions, cfg.rope_theta))
    k = shard_seq(apply_rope(k, positions, cfg.rope_theta))
    scale = Dh ** -0.5

    if cfg.attn_impl == "pallas" and causal:
        from ..kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   softcap=cfg.attn_softcap, scale=scale)
    elif cfg.attn_impl in ("blocked", "blocked_unroll"):
        if window is None:
            # unbounded attention: context-parallel shard_map path
            out = _attend_cp(q, k, v, causal=causal,
                             softcap=cfg.attn_softcap, scale=scale,
                             unroll=cfg.attn_impl == "blocked_unroll",
                             scores_f32=cfg.attn_scores_f32)
        else:
            # bounded window: static k slices keep chunks small everywhere
            out = _attend_blocked(q, k, v, causal=causal, window=window,
                                  softcap=cfg.attn_softcap, scale=scale,
                                  scores_f32=cfg.attn_scores_f32)
    else:
        mask = make_causal_mask(S, S, 0, window) if causal else None
        out = _attend_einsum(q, k, v, mask, cfg.attn_softcap, scale)
    out = shard_seq(out)
    y = _unproj(out, p["wo"], x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def cross_attention(p, cfg: ModelConfig, x: jax.Array,
                    enc_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder->encoder attention; enc_kv are precomputed (B,Se,KV,Dh)."""
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], H, Dh)
    k, v = enc_kv
    out = _attend_einsum(q, k.astype(x.dtype), v.astype(x.dtype), None, None,
                         Dh ** -0.5)
    return _unproj(out, p["wo"], x.dtype)


def encode_cross_kv(p, cfg: ModelConfig, enc_out: jax.Array):
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    k = _proj(enc_out, p["wk"], KV, Dh)
    v = _proj(enc_out, p["wv"], KV, Dh)
    return k, v


# ---------------------------------------------------------------- KV cache

def _kv_int8(cfg: ModelConfig) -> bool:
    return cfg.kv_cache_dtype == "int8"


def quantize_kv(x: jax.Array):
    """Per-(token, head) symmetric int8.  x: (..., Dh) -> (q, scale(...,1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(dtype) * scale.astype(dtype))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int], abstract: bool = False) -> Dict:
    """One layer's KV cache.  Local layers get a ring buffer of window size.
    ``kv_cache_dtype='int8'`` stores quantised KV + per-(token,head) scales
    (halves the dominant decode HBM term)."""
    L = min(max_len, window) if window is not None else max_len
    shape = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    sshape = shape[:-1] + (1,)
    if _kv_int8(cfg):
        spec = {"k": (shape, jnp.int8), "v": (shape, jnp.int8),
                "k_scale": (sshape, jnp.bfloat16),
                "v_scale": (sshape, jnp.bfloat16)}
    else:
        dt = jnp.dtype(cfg.dtype)
        spec = {"k": (shape, dt), "v": (shape, dt)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in spec.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in spec.items()}


def cache_logical_axes():
    return ("batch", None, None, None)


def build_cache_from_prefill(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                             max_len: int, window: Optional[int]) -> Dict:
    """Arrange prefill K/V into the decode cache layout.

    Full cache: positions [0, S) land at slots [0, S).  Ring buffer: the last
    ``min(S, W)`` positions land at slot = position % W (so decode writes
    continue seamlessly).
    """
    B, S = k.shape[0], k.shape[1]
    if window is None:
        L = max_len
        if L == S:
            ck, cv = k, v                      # prefill to the brim: no pad
        else:
            ck = jnp.zeros((B, L) + k.shape[2:], k.dtype)
            ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
            cv = jnp.zeros((B, L) + v.shape[2:], v.dtype)
            cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
    else:
        L = min(max_len, window)
        n = min(S, L)
        pos = jnp.arange(S - n, S)
        slots = jnp.mod(pos, L)
        ck = jnp.zeros((B, L) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - n:])
        cv = jnp.zeros((B, L) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - n:])
    out = {"k": ck, "v": cv}
    if _kv_int8(cfg):
        kq, ks = quantize_kv(ck)
        vq, vs = quantize_kv(cv)
        out = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    # keep the cache sequence-sharded through the layer scan (matches the
    # decode cache layout; otherwise the scan ys buffer replicates over model)
    return {kk: shard(vv, "kv_batch", "kv_seq", None, None)
            for kk, vv in out.items()}


def decode_self_attention(p, cfg: ModelConfig, x: jax.Array, cache: Dict,
                          pos: jax.Array, window: Optional[int]):
    """One-token decode: update cache at ``pos``, attend over it.

    x: (B, 1, D); pos: scalar int32 OR per-slot (B,) vector (continuous
    batching serves requests at different positions in one tick).
    Ring-buffer writes for local layers keep the cache O(window).
    """
    B, _, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = _proj(x, p["wq"], H, Dh)
    k = _proj(x, p["wk"], KV, Dh)
    v = _proj(x, p["wv"], KV, Dh)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1)[:, None],
                            (B, 1))                    # (B,1)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)

    L = cache["k"].shape[1]
    slot = jnp.mod(posb, L) if window is not None else posb      # (B,1)
    # elementwise where-update instead of dynamic_update_slice: DUS on the
    # sequence-sharded cache dim makes the SPMD partitioner all-gather the
    # whole cache per layer (measured +16 GB temp on decode_32k); a select
    # partitions cleanly and fuses into the attention read.
    lidx = jax.lax.broadcasted_iota(jnp.int32, (1, L, 1, 1), 1)
    sel = lidx == slot[:, 0][:, None, None, None]                # (B,L,1,1)
    new_cache = {}
    if _kv_int8(cfg):
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache["k"] = jnp.where(sel, kq, cache["k"])
        new_cache["v"] = jnp.where(sel, vq, cache["v"])
        new_cache["k_scale"] = jnp.where(sel, ks, cache["k_scale"])
        new_cache["v_scale"] = jnp.where(sel, vs, cache["v_scale"])
        ck = dequantize_kv(new_cache["k"], new_cache["k_scale"], dt)
        cv = dequantize_kv(new_cache["v"], new_cache["v_scale"], dt)
    else:
        new_cache["k"] = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
        new_cache["v"] = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
        ck, cv = new_cache["k"], new_cache["v"]
    new_cache = {kk: shard(vv, "kv_batch", "kv_seq", None, None)
                 for kk, vv in new_cache.items()}
    ck = shard(ck, "kv_batch", "kv_seq", None, None)
    cv = shard(cv, "kv_batch", "kv_seq", None, None)

    # valid slots: ring buffer holds positions (pos-L, pos]; full cache <= pos
    idx = jnp.arange(L)[None, :]                                 # (1,L)
    if window is not None:
        slot_pos = posb - jnp.mod(slot - idx, L)     # stored position per slot
        valid = (slot_pos >= 0) & (slot_pos > posb - window)     # (B,L)
    else:
        valid = idx <= posb                                      # (B,L)

    if cfg.attn_impl == "pallas":
        from ..kernels import ops as kops
        out = kops.decode_attention(q, ck.astype(dt), cv.astype(dt),
                                    valid, softcap=cfg.attn_softcap,
                                    scale=Dh ** -0.5)
    else:
        mask = valid[:, None, None, :]                           # (B,1,1,L)
        out = _attend_einsum(q, ck.astype(dt), cv.astype(dt), mask,
                             cfg.attn_softcap, Dh ** -0.5)
    y = _unproj(out, p["wo"], dt)
    return y, new_cache
