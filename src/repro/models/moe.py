"""Mixture-of-Experts: shared + routed experts, top-k routing.

Two dispatch implementations (perf lever, see EXPERIMENTS.md §Perf):

* ``onehot`` — GShard/Switch-style capacity dispatch via one-hot einsums.
  Fully dense, MXU-friendly, the classic TPU formulation; but dispatch FLOPs
  scale with group size and dominate for fine-grained experts.
* ``sort`` — sort-based gather/scatter routing: tokens are sorted by expert,
  sliced into equal-capacity bins, processed with a batched matmul, and
  scattered back.  No dispatch matmuls: the routing becomes memory movement,
  which is what a TPU gather/scatter engine is for.

Both honour a capacity factor (tokens over capacity are dropped — their
residual stream passes through, standard for capacity-based MoE).
Experts shard over the ``model`` mesh axis (expert parallelism); the router
runs in fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .layers import apply_mlp, init_mlp
from .params import ParamStore

MOE_GROUP_SIZE = 2048      # tokens per routing group (onehot path)
MOE_IMPL = ("onehot", "sort")


def init_moe(ps: ParamStore, path: str, cfg: ModelConfig,
             stacked: Optional[int]):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    pre = (stacked,) if stacked else ()
    pax = (None,) if stacked else ()
    ps.param(f"{path}/router", pre + (D, E), pax + ("fsdp", None), "fan_in",
             dtype=jnp.float32)
    ps.param(f"{path}/w_gate", pre + (E, D, F), pax + ("expert", "fsdp", None), "fan_in")
    ps.param(f"{path}/w_in", pre + (E, D, F), pax + ("expert", "fsdp", None), "fan_in")
    ps.param(f"{path}/w_out", pre + (E, F, D), pax + ("expert", None, "fsdp"), "fan_in")
    if cfg.num_shared_experts:
        init_mlp(ps, f"{path}/shared", cfg,
                 cfg.moe_d_ff * cfg.num_shared_experts, stacked)


def _router_probs(p, cfg: ModelConfig, x: jax.Array):
    """(T, E) f32 probabilities + (T, k) top-k indices/weights."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)                  # (T,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)  # renormalise
    return probs, topi, topw


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, (c + 7) // 8 * 8)


# ---------------------------------------------------------------- onehot path

def _moe_onehot(p, cfg: ModelConfig, xg: jax.Array) -> jax.Array:
    """xg: (G, S, D) grouped tokens -> (G, S, D)."""
    G, S, D = xg.shape
    E, k = cfg.num_experts, cfg.top_k
    C = _capacity(S, cfg)
    dt = xg.dtype

    x2 = xg.reshape(G * S, D)
    probs, topi, topw = _router_probs(p, cfg, x2)
    topi = topi.reshape(G, S, k)
    topw = topw.reshape(G, S, k).astype(jnp.float32)

    # position of each (token, choice) within its expert's capacity
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)             # (G,S,k,E)
    flat = onehot.reshape(G, S * k, E)        # lexicographic (token, choice)
    pos4 = (jnp.cumsum(flat, axis=1) - flat).reshape(G, S, k, E)  # (G,S,k,E)

    # dispatch/combine (G,S,E,C) accumulated per choice — avoids any
    # (G,S,k,E,C) 5-D temporary
    disp = jnp.zeros((G, S, E, C), dt)
    comb = jnp.zeros((G, S, E, C), jnp.float32)
    for kk in range(k):
        oh_e = onehot[:, :, kk, :]                                # (G,S,E) int
        slot = (pos4[:, :, kk, :] * oh_e).sum(-1)                 # (G,S)
        keep = (slot < C).astype(jnp.float32)
        oh_c = jax.nn.one_hot(jnp.minimum(slot, C - 1), C,
                              dtype=jnp.float32) * keep[..., None]  # (G,S,C)
        d = oh_e.astype(jnp.float32)[..., None] * oh_c[:, :, None, :]
        disp = disp + d.astype(dt)
        comb = comb + d * topw[:, :, kk, None, None]

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)                   # (G,E,C,D)
    xe = shard(xe, "batch", "expert", None, None)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w_in"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(dt))   # (G,E,C,D)
    ye = shard(ye, "batch", "expert", None, None)
    return jnp.einsum("gsec,gecd->gsd", comb.astype(dt), ye)


# ---------------------------------------------------------------- sort path

def _moe_sort(p, cfg: ModelConfig, xg: jax.Array) -> jax.Array:
    """Sort-based routing: (G,S,D) -> (G,S,D) with no dispatch matmuls."""
    G, S, D = xg.shape
    E, k = cfg.num_experts, cfg.top_k
    C = _capacity(S, cfg)
    dt = xg.dtype

    def per_group(x):                                            # (S, D)
        probs, topi, topw = _router_probs(p, cfg, x)             # (S,k)
        tok = jnp.tile(jnp.arange(S, dtype=jnp.int32)[:, None], (1, k)).reshape(-1)
        eid = topi.reshape(-1)
        w = topw.reshape(-1)
        order = jnp.argsort(eid, stable=True)                    # group by expert
        eid_s, tok_s, w_s = eid[order], tok[order], w[order]
        # slot within expert = rank - first_rank_of_expert
        ranks = jnp.arange(S * k, dtype=jnp.int32)
        first = jnp.searchsorted(eid_s, jnp.arange(E, dtype=jnp.int32),
                                 side="left").astype(jnp.int32)
        slot = ranks - first[eid_s]
        keep = slot < C
        dest = eid_s * C + jnp.minimum(slot, C - 1)
        # gather tokens into (E*C, D) bins
        xbin = jnp.zeros((E * C, D), dt).at[dest].add(
            jnp.where(keep[:, None], x[tok_s], 0).astype(dt))
        xbin = xbin.reshape(E, C, D)
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", xbin, p["w_gate"].astype(dt))) * \
            jnp.einsum("ecd,edf->ecf", xbin, p["w_in"].astype(dt))
        ybin = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))
        y = jnp.zeros((S, D), jnp.float32).at[tok_s].add(
            jnp.where(keep, w_s, 0.0)[:, None]
            * ybin.reshape(E * C, D)[dest].astype(jnp.float32))
        return y.astype(dt)

    return jax.vmap(per_group)(xg)


# ---------------------------------------------------------------- public API

def apply_moe(p, cfg: ModelConfig, x: jax.Array,
              impl: str = "onehot", group_size: int = MOE_GROUP_SIZE) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  Routed experts + optional shared experts."""
    B, S, D = x.shape
    T = B * S
    gs = min(group_size, T)
    G = T // gs
    assert G * gs == T, f"tokens {T} not divisible by group size {gs}"
    xg = x.reshape(G, gs, D)
    xg = shard(xg, "batch", None, None)
    if impl == "onehot":
        y = _moe_onehot(p, cfg, xg)
    elif impl == "sort":
        y = _moe_sort(p, cfg, xg)
    else:
        raise ValueError(f"moe impl {impl!r}")
    y = y.reshape(B, S, D)
    if cfg.num_shared_experts:
        y = y + apply_mlp(p["shared"], cfg, x)
    return y
