"""Pipeline parallelism: GPipe schedule over the ``pod`` mesh axis.

At 1000+ node scale the cross-pod (DCN) links are too slow for FSDP weight
gathers; the classic alternative is pipeline stages across pods with DP/TP
inside each pod.  This module implements that as a drop-in replacement for
the layer-stack scan:

* the layer-stacked parameters' leading repeat dim is sharded over ``pod``
  (stage s holds repeats [s·R/P, (s+1)·R/P));
* a partial-manual ``shard_map`` (manual over ``pod`` only — ``data`` and
  ``model`` sharding stay automatic inside the body, so TP/DP/FSDP compose);
* the GPipe tick loop runs M + P − 1 ticks; activations hop stages via
  ``lax.ppermute`` (differentiable: backward is the reverse permute, i.e.
  the standard 1F1B-ish backward bubble under ``jax.grad``);
* microbatch outputs are collected on the last stage and combined with a
  masked ``psum`` (the embedding/LM head run outside the pipeline on every
  pod — vocab stays sharded over ``model``).

Enabled via ``cfg.pipeline_stages > 1`` (requires repeats % stages == 0,
decoder-only stacks); batch sharding should map to ``data`` only (the
``pod`` axis carries stages, not data) — see ``rules_for(kind='train_pp')``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..sharding import current_mesh, logical_to_pspec, shard


def _pspec_with_pod_stage(leaf_ndim: int) -> P:
    return P(*(("pod",) + (None,) * (leaf_ndim - 1)))


def pipeline_stack(params_stack: Dict, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, one_repeat, num_microbatches: int):
    """Run the scanned superblock stack as a GPipe pipeline over ``pod``.

    ``one_repeat(x, param_slice) -> x`` applies one superblock (the same
    body the scan path uses).  Returns the stack output for the full batch.
    """
    mesh = current_mesh()
    assert mesh is not None and "pod" in mesh.axis_names, \
        "pipeline_stages > 1 needs a mesh with a 'pod' axis"
    stages = mesh.shape["pod"]
    reps = jax.tree.leaves(params_stack)[0].shape[0]
    assert reps % stages == 0, f"repeats {reps} % stages {stages} != 0"
    B = x.shape[0]
    M = num_microbatches
    assert B % M == 0, f"batch {B} % microbatches {M} != 0"

    bspec = logical_to_pspec(["batch"])
    bax = bspec[0] if len(bspec) else None
    assert bax != "pod" and (not isinstance(bax, tuple) or "pod" not in bax), \
        "pipeline mode: batch must not shard over 'pod' (use kind='train_pp')"

    # partial-manual shard_map: specs may only name the manual axis ('pod');
    # data/model shardings of x pass through the auto axes untouched
    in_specs = (
        jax.tree.map(lambda l: _pspec_with_pod_stage(l.ndim), params_stack),
        P(*([None] * x.ndim)),
    )
    out_specs = P(*([None] * x.ndim))

    def body(pl, xb):
        sid = jax.lax.axis_index("pod")
        mb = xb.reshape(M, B // M, *xb.shape[1:])
        # pin the microbatch/queue buffers' batch dim to the data axis:
        # without this XLA auto-shards the tick-loop state over M and the
        # 512-way partitioner trips on the reshard (hard crash on XLA:CPU)
        baxes = [None, "batch"] + [None] * (xb.ndim - 1)
        mb = shard(mb, *baxes)
        state = shard(jnp.zeros_like(mb[0]), "batch",
                      *([None] * (xb.ndim - 1)))
        outs = shard(jnp.zeros_like(mb), *baxes)

        def stage_fn(h):
            def step(c, psl):
                return one_repeat(c, psl), None
            h, _ = jax.lax.scan(step, h, pl)
            return h

        def tick(carry, t):
            state, outs = carry
            inject = mb[jnp.clip(t, 0, M - 1)]
            cur = jnp.where(sid == 0, inject, state)
            y = stage_fn(cur)
            perm = [(i, i + 1) for i in range(stages - 1)]
            nxt = jax.lax.ppermute(y, "pod", perm)
            oi = jnp.clip(t - (stages - 1), 0, M - 1)
            take = jnp.logical_and(sid == stages - 1, t >= stages - 1)
            outs = outs.at[oi].set(jnp.where(take, y, outs[oi]))
            return (nxt, outs), None

        (state, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(M + stages - 1, dtype=jnp.int32))
        # only the last stage holds real outputs; masked psum replicates them
        outs = jax.lax.psum(
            jnp.where(sid == stages - 1, outs, jnp.zeros_like(outs)), "pod")
        return outs.reshape(B, *xb.shape[1:])

    fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, axis_names={"pod"},
                       check_vma=False)
    return fn(params_stack, x)
