"""Mamba2 block — SSD (state-space duality) with chunked computation.

Train/prefill uses the chunked SSD formulation (intra-chunk quadratic block +
inter-chunk state recurrence over ``lax.scan``): MXU-friendly matmuls, O(S)
memory, and the honest FLOPs count for the dry-run roofline.  Decode is the
O(1)-per-token recurrent step on (conv, ssm) state.

``attn_impl == "pallas"`` routes the inner SSD chunk computation through the
``repro.kernels.ssd_scan`` TPU kernel (same math, VMEM-tiled).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .params import ParamStore

SSD_CHUNK = 256


def init_mamba(ps: ParamStore, path: str, cfg: ModelConfig,
               stacked: Optional[int]):
    D = cfg.d_model
    Din = cfg.d_inner                      # expand * d_model
    H = cfg.ssm_heads                      # Din // head_dim
    N = cfg.ssm_state
    conv_ch = Din + 2 * N                  # x, B, C are convolved
    pre = (stacked,) if stacked else ()
    pax = (None,) if stacked else ()
    # split projections so each output dim shards cleanly over `model`
    # (the fused width 2·Din+2·N+H is generally not divisible by 16)
    ps.param(f"{path}/in_z", pre + (D, Din), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/in_xbc", pre + (D, conv_ch), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/in_dt", pre + (D, H), pax + ("fsdp", None), "fan_in")
    ps.param(f"{path}/conv_w", pre + (cfg.conv_width, conv_ch), pax + (None, "model"),
             "normal", scale=0.1)
    ps.param(f"{path}/conv_b", pre + (conv_ch,), pax + ("model",), "zeros")
    ps.param(f"{path}/A_log", pre + (H,), pax + (None,), "zeros", dtype=jnp.float32)
    ps.param(f"{path}/D", pre + (H,), pax + (None,), "ones", dtype=jnp.float32)
    ps.param(f"{path}/dt_bias", pre + (H,), pax + (None,), "zeros", dtype=jnp.float32)
    ps.param(f"{path}/norm", pre + (Din,), pax + ("model",), "ones", dtype=jnp.float32)
    ps.param(f"{path}/out_proj", pre + (Din, D), pax + ("model", "fsdp"), "fan_in")


def _in_proj(p, x: jax.Array):
    dt_ = x.dtype
    z = jnp.einsum("...d,dm->...m", x, p["in_z"].astype(dt_))
    xBC = jnp.einsum("...d,dm->...m", x, p["in_xbc"].astype(dt_))
    dtr = jnp.einsum("...d,dm->...m", x, p["in_dt"].astype(dt_))
    return z, xBC, dtr


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq.  x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, k:k + x.shape[1], :] * w[k].astype(x.dtype) for k in range(K))
    return y + b.astype(x.dtype)


def _gated_rmsnorm(y: jax.Array, z: jax.Array, w: jax.Array,
                   eps: float) -> jax.Array:
    dt = y.dtype
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * w).astype(dt)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None, use_kernel: bool = False):
    """Chunked SSD.  x:(B,L,H,P) dt:(B,L,H) A:(H,) Bm,Cm:(B,L,N).

    Returns (y, h_last) with y:(B,L,H,P), h_last:(B,H,P,N).
    h_t = h_{t-1}·exp(A·dt_t) + dt_t·x_t⊗B_t ;  y_t = h_t·C_t
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert nc * chunk == L, f"L={L} not divisible by chunk={chunk}"
    dtt = x.dtype

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    dA = dtc * A                                           # (B,nc,c,H) f32, <=0
    cs = jnp.cumsum(dA, axis=2)                            # inclusive cumsum

    if use_kernel:
        from ..kernels import ops as kops
        return kops.ssd_scan(xc, dtc, dA, cs, Bc, Cc, h0=h0)

    # ---- intra-chunk (diagonal block) -------------------------------------
    # decay(i, j) = exp(cs_i - cs_j) for i >= j  (per head)
    di = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,nc,c,c,H)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(di), 0.0)
    att = jnp.einsum("bzin,bzjn->bzij", Cc.astype(jnp.float32),
                     Bc.astype(jnp.float32))               # (B,nc,c,c)
    w = att[..., None] * decay * dtc[:, :, None, :, :]     # (B,nc,c,c,H)
    y_diag = jnp.einsum("bzijh,bzjhp->bzihp", w.astype(dtt), xc)

    # ---- chunk summary states ---------------------------------------------
    # S_z = sum_j exp(cs_last - cs_j) dt_j  B_j ⊗ x_j      (B,nc,H,P,N)
    seg = jnp.exp(cs[:, :, -1:, :] - cs) * dtc             # (B,nc,c,H)
    states = jnp.einsum("bzch,bzchp,bzcn->bzhpn", seg.astype(dtt), xc, Bc)

    # ---- inter-chunk recurrence (scan over nc) ----------------------------
    chunk_decay = jnp.exp(cs[:, :, -1, :])                 # (B,nc,H)
    h_init = (jnp.zeros((B, H, P, N), dtt) if h0 is None else h0.astype(dtt))

    def step(h, inp):
        dcy, s = inp                                       # (B,H) , (B,H,P,N)
        h_new = h * dcy[..., None, None].astype(dtt) + s
        return h_new, h

    (h_last, h_prevs) = jax.lax.scan(
        step, h_init, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prevs.transpose(1, 0, 2, 3, 4)              # state entering chunk

    # ---- inter-chunk contribution  y_off = C_i · exp(cs_i) · h_prev -------
    inter = jnp.exp(cs)                                    # (B,nc,c,H) f32
    y_off = jnp.einsum("bzcn,bzch,bzhpn->bzchp", Cc,
                       inter.astype(dtt), h_prev)
    y = (y_diag + y_off).reshape(B, L, H, P)
    return y, h_last


def apply_mamba(p, cfg: ModelConfig, x: jax.Array, chunk: int = SSD_CHUNK,
                return_cache: bool = False):
    """Train/prefill forward.  x: (B,S,D) -> (B,S,D) [+ decode cache]."""
    B, S, D = x.shape
    Din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype

    z, xBC, dtr = _in_proj(p, x)
    xBC = shard(xBC, "batch", None, "model")
    xBC_conv = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = (xBC_conv[..., :Din], xBC_conv[..., Din:Din + N],
                  xBC_conv[..., Din + N:])
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                # (H,) negative

    xh = xs.reshape(B, S, H, P)
    y, h_last = ssd_chunked(xh, dt, A, Bm, Cm, min(chunk, S),
                            use_kernel=(cfg.attn_impl == "pallas"))
    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, Din)
    y = _gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsm,md->bsd", y, p["out_proj"].astype(dt_))
    out = shard(out, "batch", None, None)
    if not return_cache:
        return out
    K = cfg.conv_width
    cache = {"conv": xBC[:, S - (K - 1):, :],               # pre-activation taps
             "ssm": h_last.astype(jnp.float32)}
    return out, cache


# ---------------------------------------------------------------- decode

def init_mamba_cache(cfg: ModelConfig, batch: int, abstract: bool = False) -> Dict:
    Din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = Din + 2 * N
    dt = jnp.dtype(cfg.dtype)
    shapes = {
        "conv": ((batch, cfg.conv_width - 1, conv_ch), dt),
        "ssm": ((batch, H, P, N), jnp.float32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def decode_mamba(p, cfg: ModelConfig, x: jax.Array, cache: Dict):
    """One-token step.  x: (B,1,D) -> (B,1,D), updated cache."""
    B = x.shape[0]
    Din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype

    z, xBC, dtr = _in_proj(p, x)
    xBC = xBC[:, 0]                                         # (B, conv_ch)

    conv_hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)
    w = p["conv_w"].astype(dt_)                             # (K, C)
    conv_out = jnp.einsum("bkc,kc->bc", conv_hist, w) + p["conv_b"].astype(dt_)
    xBC_t = jax.nn.silu(conv_out)
    xs, Bm, Cm = (xBC_t[..., :Din], xBC_t[..., Din:Din + N],
                  xBC_t[..., Din + N:])

    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])                                # (H,)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                 # (B,H)
    upd = (dt[..., None, None] * xh[..., None]
           * Bm.astype(jnp.float32)[:, None, None, :])      # (B,H,P,N)
    h = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, Din).astype(dt_)
    y = _gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsm,md->bsd", y, p["out_proj"].astype(dt_))
    return out, {"conv": conv_hist[:, 1:, :], "ssm": h}
