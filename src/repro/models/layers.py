"""Shared layers: norms, RoPE, MLPs, embeddings.

Pure functional JAX: ``init_*`` declares parameters into a ParamStore,
``apply_*`` consumes the resulting pytree.  Activations are annotated with
logical sharding axes (no-ops outside a mesh context).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .params import ParamStore


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- RMSNorm

def init_rmsnorm(ps: ParamStore, path: str, dim: int, stacked: Optional[int]):
    shape = (stacked, dim) if stacked else (dim,)
    axes = (None, "embed") if stacked else ("embed",)
    ps.param(f"{path}/scale", shape, axes, init="ones", dtype=jnp.float32)


def apply_rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


# ---------------------------------------------------------------- RoPE

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                   # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (...,S,half)
    cos = jnp.cos(angles)[..., None, :]                            # (...,S,1,half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------- MLP

def init_mlp(ps: ParamStore, path: str, cfg: ModelConfig, d_ff: int,
             stacked: Optional[int]):
    D, F = cfg.d_model, d_ff
    pre = (stacked,) if stacked else ()
    pax = (None,) if stacked else ()
    gated = cfg.act in ("silu", "geglu")
    if gated:
        ps.param(f"{path}/w_gate", pre + (D, F), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/w_in", pre + (D, F), pax + ("fsdp", "model"), "fan_in")
    ps.param(f"{path}/w_out", pre + (F, D), pax + ("model", "fsdp"), "fan_in")


def apply_mlp(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = shard(h, "batch", None, "model")
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(x.dtype))


# ---------------------------------------------------------------- embeddings

def init_embeddings(ps: ParamStore, cfg: ModelConfig):
    # std 1/sqrt(D): with the sqrt(D) embedding multiplier the residual
    # stream starts at unit RMS and tied logits stay O(1)
    ps.param("embed/tok", (cfg.padded_vocab, cfg.d_model), ("model", "fsdp"),
             "normal", scale=cfg.d_model ** -0.5)
    if not cfg.tie_embeddings:
        ps.param("embed/head", (cfg.d_model, cfg.padded_vocab),
                 ("fsdp", "model"), "fan_in")


def embed_tokens(p, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    emb = p["embed"]["tok"].astype(dtype_of(cfg))
    x = jnp.take(emb, tokens, axis=0)
    x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)     # gemma-style scale
    return shard(x, "batch", None, None)


def lm_logits(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["embed"]["tok"].astype(x.dtype)               # (V, D)
        logits = jnp.einsum("...d,vd->...v", x, w)
    else:
        w = p["embed"]["head"].astype(x.dtype)              # (D, V)
        logits = jnp.einsum("...d,dv->...v", x, w)
    if cfg.final_softcap:
        c = jnp.asarray(cfg.final_softcap, logits.dtype)
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:        # mask vocab-padding columns
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return shard(logits, "batch", None, "model")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in f32.  logits: (B,S,V); labels: (B,S) int."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
