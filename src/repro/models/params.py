"""Parameter store: one declaration -> arrays, shape structs AND shardings.

Model init code declares every parameter once (path, shape, logical axes);
the store can materialise real arrays (smoke tests / training), abstract
``ShapeDtypeStruct``s (dry-run lowering — no allocation), and the matching
``PartitionSpec`` tree (pjit in/out shardings) from the same declaration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..sharding import logical_to_pspec

Pytree = Any


def _set_path(tree: Dict, path: str, leaf: Any) -> None:
    parts = path.split("/")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    assert parts[-1] not in tree, f"duplicate param {path}"
    tree[parts[-1]] = leaf


class ParamStore:
    """Collects parameter declarations during a model's ``init`` walk."""

    def __init__(self, rng: Optional[jax.Array], dtype: jnp.dtype,
                 abstract: bool = False):
        self.rng = rng
        self.dtype = dtype
        self.abstract = abstract
        self.params: Dict = {}
        self.specs: Dict = {}
        self.logical: Dict = {}

    def param(self, path: str, shape: Sequence[int],
              axes: Sequence[Optional[str]], init: str = "normal",
              scale: Optional[float] = None, dtype: Optional[jnp.dtype] = None):
        shape = tuple(int(s) for s in shape)
        assert len(axes) == len(shape), f"{path}: axes {axes} vs shape {shape}"
        dt = dtype or self.dtype
        if self.abstract:
            leaf = jax.ShapeDtypeStruct(shape, dt)
        else:
            key = jax.random.fold_in(self.rng, zlib_crc(path))
            if init == "normal":
                s = scale if scale is not None else 0.02
                leaf = (jax.random.normal(key, shape, jnp.float32) * s).astype(dt)
            elif init == "fan_in":
                fan = max(shape[0] if len(shape) == 1 else int(np.prod(shape[:-1])), 1)
                s = (scale if scale is not None else 1.0) / np.sqrt(fan)
                leaf = (jax.random.normal(key, shape, jnp.float32) * s).astype(dt)
            elif init == "zeros":
                leaf = jnp.zeros(shape, dt)
            elif init == "ones":
                leaf = jnp.ones(shape, dt)
            else:
                raise ValueError(f"unknown init {init!r}")
        _set_path(self.params, path, leaf)
        _set_path(self.specs, path, logical_to_pspec(axes))
        _set_path(self.logical, path, tuple(axes))
        return leaf


def zlib_crc(s: str) -> int:
    import zlib
    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def tree_pspecs_from_logical(logical_tree: Pytree) -> Pytree:
    """Re-map a logical-axes tree to PartitionSpecs under the current rules."""
    return jax.tree.map(
        lambda axes: logical_to_pspec(axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
