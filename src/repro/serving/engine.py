"""Batched serving engine: prefill + greedy decode with slot-based
continuous batching.

The engine keeps a fixed number of batch *slots* (the jit shape); requests
are admitted into free slots, prefilled, and decoded step-by-step; finished
slots are recycled without recompiling.  Slots decode at their OWN positions
(the model's decode path takes a per-slot position vector).  Request arrivals
can be driven by the DS3 job generator (``repro.core.jobgen``) — the paper's
workload model feeding its pod-scale twin.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    # filled by the engine:
    output: Optional[List[int]] = None
    finish_s: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_s is None else self.finish_s - self.arrival_s


class ServeEngine:
    def __init__(self, model: Model, params, num_slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.S = num_slots
        self.max_len = max_len
        self.eos = eos_id
        self.cache = model.init_cache(num_slots, max_len)
        self.pos = np.zeros(num_slots, dtype=np.int32)    # next write position
        self.active: List[Optional[Request]] = [None] * num_slots
        self.last_tok = np.zeros(num_slots, dtype=np.int32)
        self.ticks = 0

        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))

    # ---------------------------------------------------------------- admit
    def _admit(self, req: Request, slot: int):
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        logits, cache1 = self._prefill(self.params, batch)
        self.cache = jax.tree_util.tree_map_with_path(
            lambda path, buf, new: _scatter_slot(
                buf, new, slot,
                stacked=any(getattr(k, "key", None) == "stack" for k in path)),
            self.cache, cache1)
        self.pos[slot] = len(req.prompt)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.output = [nxt]
        self.last_tok[slot] = nxt
        self.active[slot] = req

    # ---------------------------------------------------------------- step
    def step(self):
        """One decode tick for all active slots (per-slot positions)."""
        act = [i for i, r in enumerate(self.active) if r is not None]
        if not act:
            return
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)                       # (S,) per-slot
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.ticks += 1
        # engine-relative monotonic clock (perf_counter: immune to wall-clock
        # adjustments, unlike time.time)
        now = time.perf_counter() - getattr(self, "_t0", 0.0)
        for i in act:
            r = self.active[i]
            nxt = int(jnp.argmax(logits[i, -1]))
            r.output.append(nxt)
            self.last_tok[i] = nxt
            self.pos[i] += 1
            done = (len(r.output) >= r.max_new_tokens
                    or (self.eos is not None and nxt == self.eos)
                    or self.pos[i] >= self.max_len - 1)
            if done:
                r.finish_s = now
                self.active[i] = None

    # ---------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> List[Request]:
        """Process requests to completion (arrival-ordered admission)."""
        pending = sorted(requests, key=lambda r: r.arrival_s)
        t0 = time.perf_counter()
        self._t0 = t0
        while pending or any(r is not None for r in self.active):
            now = time.perf_counter() - t0
            for i in range(self.S):
                if self.active[i] is None and pending and \
                        pending[0].arrival_s <= now:
                    self._admit(pending.pop(0), i)
            if any(r is not None for r in self.active):
                self.step()
            elif pending:
                time.sleep(min(0.001, pending[0].arrival_s - now))
        return requests


def _scatter_slot(buf: jax.Array, new: jax.Array, slot: int,
                  stacked: bool) -> jax.Array:
    """Write request-cache ``new`` (batch=1) into slot ``slot`` of ``buf``.

    Scan-stacked leaves are (R, B, ...) vs new (R, 1, ...); tail leaves are
    (B, ...) vs (1, ...)."""
    if stacked:
        return buf.at[:, slot].set(new[:, 0].astype(buf.dtype))
    return buf.at[slot].set(new[0].astype(buf.dtype))
