from .pipeline import DataState, SyntheticLMPipeline

__all__ = ["DataState", "SyntheticLMPipeline"]
