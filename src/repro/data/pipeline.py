"""Deterministic synthetic LM data pipeline.

Production properties the trainer relies on:

* **Stateless addressing** — ``batch_at(step)`` is a pure function of
  (seed, step), so a restart resumes mid-epoch with zero drift and no
  replayed/skipped batches (the data state in a checkpoint is just
  ``{seed, step}``).
* **Host sharding** — each host materialises only its slice of the global
  batch (``host_index/host_count``), matching multi-host TPU input loading.
* **Learnable structure** — tokens follow a Zipf marginal with a first-order
  Markov mixing kernel, so cross-entropy has headroom below uniform and a
  real model trains visibly in a few hundred steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> Dict:
        return {"seed": int(self.seed), "step": int(self.step)}

    @classmethod
    def from_dict(cls, d: Dict) -> "DataState":
        return cls(int(d["seed"]), int(d["step"]))


class SyntheticLMPipeline:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 zipf_a: float = 1.2, markov_weight: float = 0.7):
        assert batch % host_count == 0
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.host_index = host_index
        self.host_count = host_count
        self.state = DataState(seed, 0)
        self.markov_weight = markov_weight
        # Zipf marginal over the vocab (heavy head, long tail)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self._probs = p / p.sum()

    # -- pure addressing ----------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global-batch slice for this host at ``step`` (pure function)."""
        per_host = self.batch // self.host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step, self.host_index]))
        base = rng.choice(self.vocab, size=(per_host, self.seq + 1),
                          p=self._probs)
        # first-order Markov structure: with prob w, next token is a
        # deterministic mix of the previous one (learnable transitions)
        mix = rng.random((per_host, self.seq + 1)) < self.markov_weight
        shifted = (base[:, :-1] * 31 + 17) % self.vocab
        tokens = base.copy()
        tokens[:, 1:] = np.where(mix[:, 1:], shifted, base[:, 1:])
        tokens = tokens.astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    # -- iterator protocol ---------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # -- checkpointing --------------------------------------------------------
    def state_dict(self) -> Dict:
        return self.state.to_dict()

    def load_state_dict(self, d: Dict) -> None:
        self.state = DataState.from_dict(d)
