"""Pytest hook for the CC001 compile-count gate.

Load with ``-p repro.analysis.pytest_plugin`` (or via the ``pytest11``
entry point when the package is installed) and point it at the artifacts::

    pytest -p repro.analysis.pytest_plugin \
        --compile-contracts src/repro/analysis/contracts.json \
        --compile-bench 'BENCH_*.json'

After the test session the gate runs over every matching ``BENCH_*.json``;
violations print as lint findings and flip the session exit status to 1, so
a compile-count regression fails CI even when every test passed.
"""
from __future__ import annotations

import glob
from pathlib import Path


def pytest_addoption(parser):
    group = parser.getgroup("repro.analysis")
    group.addoption("--compile-contracts", default=None, metavar="PATH",
                    help="contracts.json for the CC001 compile-count gate")
    group.addoption("--compile-bench", default="BENCH_*.json",
                    metavar="GLOB",
                    help="glob of bench artifacts to gate "
                         "(default: BENCH_*.json)")


def pytest_sessionfinish(session, exitstatus):
    contracts = session.config.getoption("--compile-contracts")
    if not contracts:
        return
    from .compile_gate import check_compile_gate
    pattern = session.config.getoption("--compile-bench")
    bench_paths = sorted(Path(p) for p in glob.glob(pattern))
    tr = session.config.pluginmanager.get_plugin("terminalreporter")

    def say(line):
        if tr is not None:
            tr.write_line(line)
        else:                                             # pragma: no cover
            print(line)

    if not bench_paths:
        say(f"[repro.analysis] CC001: no bench artifacts match "
            f"{pattern!r}; gate skipped")
        return
    findings = check_compile_gate(Path(contracts), bench_paths)
    if findings:
        for f in findings:
            say(f.render())
        say(f"[repro.analysis] CC001: {len(findings)} compile-count "
            f"violation(s)")
        session.exitstatus = 1
    else:
        say(f"[repro.analysis] CC001: {len(bench_paths)} bench artifact(s) "
            f"within contract")
