"""Repo-root configuration for ``repro.analysis`` (``pyproject.toml``).

The ``[tool.repro.analysis]`` block selects rules, lint paths, per-rule
severity and the UN001 unit vocabulary::

    [tool.repro.analysis]
    paths = ["src/repro", "benchmarks", "examples"]
    disable = []                      # rule codes switched off repo-wide
    severity = ["SH001=warn"]         # per-rule level: error | warn | info
    unit-suffixes = ["_j", "_w", ...] # accepted unit suffixes (UN001)
    unit-structs = ["EnergyReport"]   # dataclasses UN001 audits
    unit-allow = ["util*", "*_idx"]   # dimensionless names (fnmatch)
    contracts = "src/repro/analysis/contracts.json"

Severity semantics: ``error`` findings gate (CLI exit 1), ``warn`` findings
print but only gate under ``--strict`` (the CI mode), ``info`` findings
never gate.  ``severity`` accepts either the ``["CODE=level", …]`` list
form above (parseable by the minimal fallback parser) or a
``[tool.repro.analysis.severity]`` sub-table when ``tomllib``/``tomli``
is available.

Python 3.10 has no ``tomllib``; a minimal single-section parser handles the
subset this block uses (strings, string lists, booleans) when neither
``tomllib`` nor ``tomli`` is importable — no new dependency.
"""
from __future__ import annotations

import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Optional, Tuple

ALL_RULES: Tuple[str, ...] = ("JX001", "JX002", "JX003", "PT001", "UN001",
                              "SC001", "DN001", "SH001", "CC001")

SEVERITY_LEVELS: Tuple[str, ...] = ("error", "warn", "info")

#: default per-rule severity; SH001's sharding-contract checks are
#: heuristics over placement conventions, so they warn by default (and
#: gate only under --strict, the CI mode)
DEFAULT_SEVERITY: Dict[str, str] = {
    "JX001": "error", "JX002": "error", "JX003": "error",
    "PT001": "error", "UN001": "error",
    "SC001": "error", "DN001": "error", "SH001": "warn",
    "CC001": "error", "WV001": "error",
}

#: one-line rule summaries (CLI --list-rules, SARIF rule metadata)
RULE_DOCS: Dict[str, str] = {
    "JX001": "tracer-leak: .item()/bool()/int()/float()/if/while on "
             "traced values in jit-reachable code",
    "JX002": "host-numpy-in-jit: np.* calls on traced data (use jnp)",
    "JX003": "impure-jit: print/wall-clock/host-RNG/global or self "
             "mutation inside jitted code",
    "PT001": "pytree-contract: register_dataclass targets frozen, "
             "data/meta split exact, meta fields hashable",
    "UN001": "unit-suffix: numeric fields and payload keys on result "
             "structs carry _us/_j/_w/_c/_hz/... suffixes",
    "SC001": "scan-carry: lax.scan/while_loop/fori_loop bodies must keep "
             "carry arity, element order and dtype stable",
    "DN001": "use-after-donate: arguments donated to a jit "
             "(donate_argnums/argnames) must not be read after the call",
    "SH001": "lane-sharding: leading-axis 'lanes' PartitionSpec, no "
             "device_put/mesh construction inside a traced body",
    "CC001": "compile-count gate: BENCH_*.json counters within "
             "contracts.json budgets",
    "WV001": "(strict only) waiver comment missing its -- justification",
}

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")
DEFAULT_SUFFIXES = ("_j", "_w", "_s", "_us", "_ms", "_c", "_hz", "_ghz")
DEFAULT_UNIT_STRUCTS = ("EnergyReport", "EvalResult", "Telemetry",
                        "GovernorPolicy", "Result", "SweepResult",
                        "TraceSpec", "ThermalSpec")
DEFAULT_UNIT_ALLOW = ("util*", "utilization", "*_idx", "*_count", "num_*",
                      "*_frac", "*_ratio", "up_threshold", "mix", "seed",
                      "bins", "repeats", "points", "schema", "kind",
                      "value", "axes", "telemetry")


@dataclasses.dataclass(frozen=True)
class AnalysisConfig:
    root: Path
    paths: Tuple[str, ...] = DEFAULT_PATHS
    disable: Tuple[str, ...] = ()
    severity: Tuple[Tuple[str, str], ...] = ()   # per-rule overrides
    unit_suffixes: Tuple[str, ...] = DEFAULT_SUFFIXES
    unit_structs: Tuple[str, ...] = DEFAULT_UNIT_STRUCTS
    unit_allow: Tuple[str, ...] = DEFAULT_UNIT_ALLOW
    contracts: str = "src/repro/analysis/contracts.json"

    def enabled_rules(self, select: Optional[List[str]] = None,
                      ignore: Optional[List[str]] = None) -> Tuple[str, ...]:
        rules = list(select) if select else [r for r in ALL_RULES
                                             if r not in self.disable]
        if ignore:
            rules = [r for r in rules if r not in ignore]
        unknown = [r for r in rules if r not in ALL_RULES]
        if unknown:
            raise ValueError(f"unknown rule code(s) {unknown}; "
                             f"known: {list(ALL_RULES)}")
        return tuple(rules)

    def severity_for(self, code: str) -> str:
        for rule, level in self.severity:
            if rule == code:
                return level
        return DEFAULT_SEVERITY.get(code, "error")


def _parse_toml(text: str) -> Dict:
    try:
        import tomllib                                   # py311+
        return tomllib.loads(text)
    except ImportError:
        pass
    try:
        import tomli
        return tomli.loads(text)
    except ImportError:
        return _parse_section(text, "tool.repro.analysis")


def _parse_section(text: str, section: str) -> Dict:
    """Tiny TOML-subset fallback: one named table of scalars/string lists."""
    table: Dict = {}
    in_section = False
    buf = ""
    for raw in text.splitlines():
        line = raw.strip()
        if line.startswith("["):
            in_section = line == f"[{section}]"
            continue
        if not in_section or not line or line.startswith("#"):
            continue
        buf += " " + line
        if buf.count("[") > buf.count("]"):
            continue                                     # multi-line list
        m = re.match(r'\s*([\w.-]+)\s*=\s*(.+)$', buf)
        buf = ""
        if not m:
            continue
        table[m.group(1)] = _parse_value(m.group(2).strip())
    # re-nest under the dotted section path so both parsers look alike
    out: Dict = {}
    node = out
    for part in section.split("."):
        node[part] = {}
        node = node[part]
    node.update(table)
    return out


def _parse_value(v: str):
    v = v.split("#", 1)[0].strip() if not v.startswith(("'", '"', "[")) else v
    if v.startswith("["):
        inner = v.strip()[1:-1]
        items = [s.strip() for s in inner.split(",") if s.strip()]
        return [_parse_value(s) for s in items]
    if v.startswith(("'", '"')):
        return v.strip()[1:-1]
    if v in ("true", "false"):
        return v == "true"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def find_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor holding a ``pyproject.toml`` (else ``start``)."""
    p = (start or Path.cwd()).resolve()
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return p


def load_config(root: Optional[Path] = None) -> AnalysisConfig:
    # an explicit root is authoritative (fixture trees have no pyproject);
    # otherwise walk up from cwd to the nearest pyproject.toml
    root = Path(root).resolve() if root is not None else find_root()
    pyproject = root / "pyproject.toml"
    block: Dict = {}
    if pyproject.is_file():
        data = _parse_toml(pyproject.read_text())
        block = data.get("tool", {}).get("repro", {}).get("analysis", {})

    def tup(key: str, default: Tuple[str, ...]) -> Tuple[str, ...]:
        val = block.get(key, block.get(key.replace("-", "_")))
        return tuple(val) if val is not None else default

    return AnalysisConfig(
        root=root,
        paths=tup("paths", DEFAULT_PATHS),
        disable=tup("disable", ()),
        severity=_parse_severity(block.get("severity")),
        unit_suffixes=tup("unit-suffixes", DEFAULT_SUFFIXES),
        unit_structs=tup("unit-structs", DEFAULT_UNIT_STRUCTS),
        unit_allow=tup("unit-allow", DEFAULT_UNIT_ALLOW),
        contracts=str(block.get("contracts",
                                "src/repro/analysis/contracts.json")),
    )


def _parse_severity(val) -> Tuple[Tuple[str, str], ...]:
    """Per-rule severity overrides: a ``{"SH001": "warn"}`` sub-table (full
    TOML parsers) or the ``["SH001=warn"]`` list form (fallback parser)."""
    if val is None:
        return ()
    if isinstance(val, dict):
        pairs = [(str(k), str(v)) for k, v in val.items()]
    else:
        pairs = []
        for item in val:
            code, _, level = str(item).partition("=")
            pairs.append((code.strip(), level.strip()))
    for code, level in pairs:
        if code not in ALL_RULES and code != "WV001":
            raise ValueError(f"severity override for unknown rule {code!r}")
        if level not in SEVERITY_LEVELS:
            raise ValueError(f"severity for {code} must be one of "
                             f"{list(SEVERITY_LEVELS)}, got {level!r}")
    return tuple(pairs)
