"""The jit-reachable set — which code runs under a JAX trace.

Roots are (a) functions decorated with ``jax.jit`` / ``jax.vmap`` /
``functools.partial(jax.jit, …)`` and (b) callees handed to trace entry
points anywhere in the project — ``jax.jit(f)``, ``jax.vmap(f)``,
``lax.scan(f, …)``, ``lax.while_loop(cond, body, …)``, ``lax.cond(p, t, f)``,
``pallas_call(kernel)`` — including lambdas and defs nested in host code.
Edges follow plain calls (and ``functools.partial`` wrapping) to top-level
functions across the indexed modules, so e.g. ``_epoch_scan`` →
``_window_step`` → ``repro.core.thermal.exact_step_jax`` all land in the set
rooted at ``@jit _simulate``.  Everything lexically inside a reachable
function (nested defs, lambdas) traces with it and is scanned as one unit.

``static_param_names`` collects every ``static_argnames`` string seen on a
jit decorator: rules treat those names as host values (not traced) even in
transitive callees — a deliberate, documented approximation that keeps
``if policy == "etf"`` (a compile-time branch) out of JX001.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .project import ModuleInfo, ProjectIndex, dotted_name

#: canonical dotted name -> positions of traced-callee arguments
TRACE_ENTRY: Dict[str, Tuple[int, ...]] = {
    "jax.jit": (0,), "jax.vmap": (0,), "jax.pmap": (0,),
    "jax.grad": (0,), "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,), "jax.remat": (0,),
    "jax.lax.scan": (0,), "jax.lax.map": (0,),
    "jax.lax.while_loop": (0, 1), "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2), "jax.lax.switch": (1,),
    "jax.lax.associative_scan": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
    "jax.experimental.shard_map.shard_map": (0,),
}

#: decorators that make the decorated function a trace root
_ROOT_DECORATORS = ("jax.jit", "jax.vmap", "jax.pmap", "jax.checkpoint",
                    "jax.remat")

FuncNode = ast.AST      # FunctionDef | AsyncFunctionDef | Lambda


@dataclasses.dataclass(frozen=True)
class Unit:
    """One reachable trace unit: a function whose whole subtree traces."""
    mod: ModuleInfo
    node: FuncNode
    name: str           # display name ("_epoch_scan", "<lambda:L123>")

    def key(self) -> Tuple[str, int]:
        return (self.mod.path, self.node.lineno)


@dataclasses.dataclass
class ReachableSet:
    units: List[Unit]
    static_param_names: frozenset

    def __iter__(self):
        return iter(self.units)


def _display(node: FuncNode) -> str:
    if isinstance(node, ast.Lambda):
        return f"<lambda:L{node.lineno}>"
    return node.name


def _static_argnames(call: ast.Call) -> List[str]:
    out: List[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.append(n.value)
    return out


def _decorator_roots(fn: ast.AST, mod: ModuleInfo,
                     static_names: Set[str]) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        dotted = dotted_name(target, mod)
        if dotted in _ROOT_DECORATORS:
            if isinstance(dec, ast.Call):
                static_names.update(_static_argnames(dec))
            return True
        if dotted == "functools.partial" and isinstance(dec, ast.Call) \
                and dec.args:
            inner = dotted_name(dec.args[0], mod)
            if inner in _ROOT_DECORATORS:
                static_names.update(_static_argnames(dec))
                return True
    return False


def _callee_targets(expr: ast.AST, mod: ModuleInfo,
                    index: ProjectIndex) -> List[Tuple[ModuleInfo, FuncNode]]:
    """Resolve a callee expression to concrete function nodes."""
    if isinstance(expr, ast.Lambda):
        return [(mod, expr)]
    if isinstance(expr, (ast.Tuple, ast.List)):        # lax.switch branches
        out = []
        for e in expr.elts:
            out.extend(_callee_targets(e, mod, index))
        return out
    if isinstance(expr, ast.Call):                     # functools.partial(f,…)
        if dotted_name(expr.func, mod) == "functools.partial" and expr.args:
            return _callee_targets(expr.args[0], mod, index)
        return []
    if isinstance(expr, ast.Name):
        # a def nested in an enclosing function shadows module scope
        scope = mod.enclosing_function(expr)
        while scope is not None:
            for n in ast.walk(scope):
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and n is not scope and n.name == expr.id:
                    return [(mod, n)]
            scope = mod.enclosing_function(scope)
    if isinstance(expr, (ast.Name, ast.Attribute)):
        dotted = dotted_name(expr, mod)
        if dotted:
            hit = index.resolve_function(dotted)
            if hit is not None:
                return [(hit[0], hit[1])]
    return []


def _call_edges(unit_node: FuncNode, mod: ModuleInfo,
                index: ProjectIndex) -> List[Tuple[ModuleInfo, FuncNode]]:
    """Top-level functions this unit's subtree calls (or partial-wraps)."""
    out: List[Tuple[ModuleInfo, FuncNode]] = []
    for node in ast.walk(unit_node):
        if not isinstance(node, ast.Call):
            continue
        dotted = dotted_name(node.func, mod)
        if dotted == "functools.partial" and node.args:
            out.extend(_callee_targets(node.args[0], mod, index))
            continue
        if dotted:
            hit = index.resolve_function(dotted)
            if hit is not None:
                out.append((hit[0], hit[1]))
    return out


def compute_reachable(index: ProjectIndex) -> ReachableSet:
    static_names: Set[str] = set()
    roots: List[Tuple[ModuleInfo, FuncNode]] = []

    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and _decorator_roots(node, mod, static_names):
                roots.append((mod, node))
            elif isinstance(node, ast.Call):
                dotted = dotted_name(node.func, mod)
                positions = TRACE_ENTRY.get(dotted or "")
                if not positions:
                    continue
                if dotted == "jax.jit":
                    static_names.update(_static_argnames(node))
                for pos in positions:
                    if pos < len(node.args):
                        roots.extend(_callee_targets(node.args[pos], mod,
                                                     index))

    # BFS over call edges; each unit is scanned whole (nested defs included),
    # so membership is tracked at unit granularity
    seen: Set[Tuple[str, int]] = set()
    units: List[Unit] = []
    frontier = list(roots)
    while frontier:
        mod, node = frontier.pop()
        unit = Unit(mod=mod, node=node, name=_display(node))
        if unit.key() in seen:
            continue
        seen.add(unit.key())
        units.append(unit)
        for tgt_mod, tgt_node in _call_edges(node, mod, index):
            if (tgt_mod.path, tgt_node.lineno) not in seen:
                frontier.append((tgt_mod, tgt_node))

    units.sort(key=lambda u: (u.mod.path, u.node.lineno))
    return ReachableSet(units=units,
                        static_param_names=frozenset(static_names))
