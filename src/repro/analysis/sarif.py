"""SARIF 2.1.0 emitter — findings as GitHub code-scanning annotations.

``python -m repro.analysis --sarif findings.sarif`` (or
``--format sarif`` on stdout) serialises the findings report as a SARIF
2.1.0 log so CI's ``analysis`` job can hand it to
``github/codeql-action/upload-sarif`` and lint findings annotate the PR
diff instead of hiding in an artifact.

Mapping:

* severity ``error``/``warn``/``info`` -> SARIF result ``level``
  ``error``/``warning``/``note`` (the same words the text renderer and
  problem matcher use);
* waived findings are emitted with a ``suppressions`` entry of kind
  ``inSource`` (GitHub hides suppressed results but keeps the audit
  trail) carrying the waiver justification;
* every known rule appears in ``tool.driver.rules`` with its one-line doc,
  so annotations link to rule metadata.
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence

from .config import DEFAULT_SEVERITY, RULE_DOCS
from .findings import Finding

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")
_TOOL_NAME = "repro.analysis"

#: severity -> SARIF result level (note SARIF says "warning", we say "warn")
_LEVEL = {"error": "error", "warn": "warning", "info": "note"}


def sarif_payload(findings: Sequence[Finding],
                  tool_version: str = "2.0") -> Dict:
    rules = [{
        "id": code,
        "name": code,
        "shortDescription": {"text": doc},
        "defaultConfiguration": {
            "level": _LEVEL.get(DEFAULT_SEVERITY.get(code, "error"),
                                "error")},
    } for code, doc in sorted(RULE_DOCS.items())]
    rule_index = {r["id"]: i for i, r in enumerate(rules)}

    results: List[Dict] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        result: Dict = {
            "ruleId": f.code,
            "level": _LEVEL.get(f.severity, "error"),
            "message": {"text": f.message},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {"uri": f.path,
                                         "uriBaseId": "SRCROOT"},
                    "region": {"startLine": f.line,
                               "startColumn": f.col + 1},
                },
            }],
        }
        if f.code in rule_index:
            result["ruleIndex"] = rule_index[f.code]
        if f.waived:
            sup: Dict = {"kind": "inSource"}
            if f.waiver_reason:
                sup["justification"] = f.waiver_reason
            result["suppressions"] = [sup]
        results.append(result)

    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": _TOOL_NAME,
                "informationUri":
                    "https://arxiv.org/abs/2003.09016",
                "version": tool_version,
                "rules": rules,
            }},
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
            "results": results,
        }],
    }


def render_sarif(findings: Sequence[Finding]) -> str:
    return json.dumps(sarif_payload(findings), indent=2)


def dump_sarif(findings: Sequence[Finding], path: str) -> None:
    with open(path, "w") as fh:
        fh.write(render_sarif(findings) + "\n")
