"""PT001 — pytree registration contracts.

Every ``jax.tree_util.register_dataclass`` (and ``register_pytree_node``)
target must be a **frozen** dataclass — sweep lanes hash scenarios and
tables as jit cache keys, and a mutable pytree silently invalidates them.
When the ``data_fields`` / ``meta_fields`` split is written as literals it
must partition the class's annotated fields exactly (a missing field is
dropped by ``flatten`` → ``unflatten`` round-trips lose state; an
overlapping or unknown field breaks unflatten), and meta (static/hashable)
fields must not be arrays — an ndarray meta field defeats hashing and
retriggers compilation per call.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from .findings import Finding
from .project import ModuleInfo, ProjectIndex, dotted_name

_REGISTER_DATACLASS = "jax.tree_util.register_dataclass"
_REGISTER_NODE = ("jax.tree_util.register_pytree_node",
                  "jax.tree_util.register_pytree_node_class")


def _dataclass_frozen(cls: ast.ClassDef, mod: ModuleInfo) -> Optional[bool]:
    """None: not a dataclass; else the ``frozen=`` flag."""
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if dotted_name(target, mod) != "dataclasses.dataclass":
            continue
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                    return bool(kw.value.value)
        return False
    return None


def _annotated_fields(cls: ast.ClassDef) -> List[Tuple[str, str]]:
    out = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            if isinstance(stmt.annotation, ast.Constant) or \
                    "ClassVar" in ast.unparse(stmt.annotation):
                continue
            out.append((stmt.target.id, ast.unparse(stmt.annotation)))
    return out


def _literal_strs(node: ast.AST) -> Optional[List[str]]:
    if not isinstance(node, (ast.List, ast.Tuple)):
        return None
    out = []
    for e in node.elts:
        if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
            return None
        out.append(e.value)
    return out


def _resolve_classes(expr: ast.AST, mod: ModuleInfo) -> List[ast.ClassDef]:
    """First argument of a register call -> candidate class defs.

    Handles the direct ``register_dataclass(SimTables, …)`` form and the
    loop form ``for _cls in (A, B, C): register_dataclass(_cls, …)``.
    """
    if not isinstance(expr, ast.Name):
        return []
    if expr.id in mod.classes:
        return [mod.classes[expr.id]]
    parent = mod.parents.get(expr)
    while parent is not None:
        if isinstance(parent, ast.For) and \
                isinstance(parent.target, ast.Name) and \
                parent.target.id == expr.id and \
                isinstance(parent.iter, (ast.Tuple, ast.List)):
            return [mod.classes[e.id] for e in parent.iter.elts
                    if isinstance(e, ast.Name) and e.id in mod.classes]
        parent = mod.parents.get(parent)
    return []


def _is_array_annotation(ann: str) -> bool:
    return "ndarray" in ann or "Array" in ann


def check_pytree_rules(index: ProjectIndex) -> List[Finding]:
    out: List[Finding] = []
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = dotted_name(node.func, mod)
            if dotted == _REGISTER_DATACLASS:
                out.extend(_check_register_dataclass(node, mod))
            elif dotted in _REGISTER_NODE and node.args:
                for cls in _resolve_classes(node.args[0], mod):
                    frozen = _dataclass_frozen(cls, mod)
                    if frozen is not True:
                        out.append(Finding(
                            code="PT001", path=mod.path, line=node.lineno,
                            col=node.col_offset,
                            message=f"pytree-registered `{cls.name}` must "
                                    f"be a frozen dataclass (hashable jit "
                                    f"cache key); found "
                                    f"{'mutable dataclass' if frozen is False else 'non-dataclass'}"))
    return out


def _check_register_dataclass(node: ast.Call,
                              mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    if not node.args:
        return out

    def finding(msg: str, line: Optional[int] = None) -> None:
        out.append(Finding(code="PT001", path=mod.path,
                           line=line or node.lineno, col=node.col_offset,
                           message=msg))

    kwargs = {kw.arg: kw.value for kw in node.keywords}
    data_node = kwargs.get("data_fields",
                           node.args[1] if len(node.args) > 1 else None)
    meta_node = kwargs.get("meta_fields",
                           node.args[2] if len(node.args) > 2 else None)
    data = _literal_strs(data_node) if data_node is not None else None
    meta = _literal_strs(meta_node) if meta_node is not None else None

    for cls in _resolve_classes(node.args[0], mod):
        frozen = _dataclass_frozen(cls, mod)
        if frozen is None:
            finding(f"`register_dataclass({cls.name}, …)` on a "
                    f"non-dataclass")
            continue
        if not frozen:
            finding(f"pytree-registered dataclass `{cls.name}` must be "
                    f"frozen=True: sweep lanes hash it as a jit cache key")
        if data is None or meta is None:
            continue                     # computed split: frozen check only
        fields = dict(_annotated_fields(cls))
        overlap = sorted(set(data) & set(meta))
        if overlap:
            finding(f"`{cls.name}` fields {overlap} listed as both data "
                    f"and meta")
        missing = sorted(set(fields) - set(data) - set(meta))
        if missing:
            finding(f"`{cls.name}` fields {missing} missing from the "
                    f"data/meta split: flatten() drops them and "
                    f"unflatten() round-trips lose state")
        unknown = sorted((set(data) | set(meta)) - set(fields))
        if unknown:
            finding(f"`{cls.name}` split names unknown fields {unknown}")
        for name in meta:
            ann = fields.get(name)
            if ann is not None and _is_array_annotation(ann):
                finding(f"`{cls.name}.{name}` is declared meta (static) "
                        f"but annotated `{ann}`: array metadata is "
                        f"unhashable and defeats the jit cache")
    return out
