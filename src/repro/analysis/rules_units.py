"""UN001 — unit-suffix discipline on result/report structs.

The paper's tables mix microseconds, Joules, Watts, Celsius and GHz; a bare
``latency`` field is how µs gets averaged into ms.  Every *numeric* field of
the configured structs (``unit-structs`` in ``[tool.repro.analysis]``), and
every string key of dict literals built inside their methods (``to_dict``
payloads feed the run manifests), must either end in an accepted unit
suffix (``_us``, ``_j``, …) or match a dimensionless allow pattern
(``util*``, ``*_idx``, ``num_*``, …).  Integer-annotated fields are exempt —
counts and indices carry no unit.

:func:`unit_violations` exposes the raw violations (struct, node, kind) so
the ``--fix`` engine (:mod:`repro.analysis.fix`) can mechanically apply the
rename the finding message suggests; :func:`check_unit_rules` renders the
same violations as findings.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from typing import Iterator, List, Optional

from .config import AnalysisConfig
from .findings import Finding
from .project import ModuleInfo, ProjectIndex

_NUMERIC_ANN = re.compile(r"\bfloat\b|ndarray|\bArray\b|jnp\.|\bcomplex\b")


def _looks_numeric(ann: str) -> bool:
    return bool(_NUMERIC_ANN.search(ann))


def _is_dataclass_like(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        src = ast.unparse(target)
        if src.endswith("dataclass") or src.endswith("NamedTuple"):
            return True
    return any(isinstance(b, ast.Name) and b.id == "NamedTuple"
               for b in cls.bases)


def _name_ok(name: str, cfg: AnalysisConfig) -> bool:
    if any(name.endswith(sfx) for sfx in cfg.unit_suffixes):
        return True
    return any(fnmatch.fnmatchcase(name, pat) for pat in cfg.unit_allow)


@dataclasses.dataclass(frozen=True)
class UnitViolation:
    """One suffix-less name on a unit struct, addressable for ``--fix``."""
    mod: ModuleInfo
    cls: ast.ClassDef
    kind: str                   # "field" | "dict_key"
    node: ast.AST               # AnnAssign (field) / Constant / keyword
    name: str
    method: Optional[str] = None   # enclosing method for dict keys


def unit_violations(index: ProjectIndex,
                    cfg: AnalysisConfig) -> Iterator[UnitViolation]:
    for mod in index.modules.values():
        for cls in mod.classes.values():
            if cls.name not in cfg.unit_structs:
                continue
            if not _is_dataclass_like(cls):
                continue
            for stmt in cls.body:
                if isinstance(stmt, ast.AnnAssign) and \
                        isinstance(stmt.target, ast.Name):
                    ann = ast.unparse(stmt.annotation)
                    if _looks_numeric(ann) and \
                            not _name_ok(stmt.target.id, cfg):
                        yield UnitViolation(mod=mod, cls=cls, kind="field",
                                            node=stmt, name=stmt.target.id)
                elif isinstance(stmt, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    for key_node, key in _dict_keys(stmt):
                        if not _name_ok(key, cfg):
                            yield UnitViolation(mod=mod, cls=cls,
                                                kind="dict_key",
                                                node=key_node, name=key,
                                                method=stmt.name)


def check_unit_rules(index: ProjectIndex,
                     cfg: AnalysisConfig) -> List[Finding]:
    out: List[Finding] = []
    suffixes = ", ".join(cfg.unit_suffixes)
    for v in unit_violations(index, cfg):
        what = "numeric field" if v.kind == "field" else \
            f"dict key (in `{v.method}()`)"
        out.append(Finding(
            code="UN001", path=v.mod.path, line=v.node.lineno,
            col=v.node.col_offset,
            message=f"{what} `{v.name}` on `{v.cls.name}` lacks a unit "
                    f"suffix ({suffixes}); rename (e.g. "
                    f"`{v.name}_us`) or add an `unit-allow` pattern"))
    return out


def _dict_keys(fn: ast.AST):
    """String keys of dict literals / dict(...) calls in a method body."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    yield k, k.value
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Name) and node.func.id == "dict":
            for kw in node.keywords:
                if kw.arg is not None:
                    yield kw, kw.arg
