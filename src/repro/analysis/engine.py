"""Orchestrator: index → reachability → rules → waivers → severity → report.

The full project index is always built (even under ``--changed``) so that
cross-module jit reachability and import resolution stay whole-program;
``only_paths`` then filters which files may *report* findings.  Nothing in
the audited tree is imported — see :mod:`repro.analysis.project`.

Severity is assigned *after* waivers: every finding carries its configured
level (``error``/``warn``/``info``), and :attr:`AnalysisReport.ok` gates on
:func:`repro.analysis.findings.gating` — ``error`` always fails the run,
``warn`` fails only under ``--strict`` (the CI mode), ``info`` never does.
"""
from __future__ import annotations

import dataclasses
import subprocess
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from .config import AnalysisConfig
from .findings import Finding, apply_waivers, gating, scan_waivers
from .project import ProjectIndex
from .reachability import compute_reachable
from .rules_donation import check_donation_rules
from .rules_jax import check_jax_rules
from .rules_pytree import check_pytree_rules
from .rules_scan import check_scan_rules
from .rules_sharding import check_sharding_rules
from .rules_units import check_unit_rules


@dataclasses.dataclass
class AnalysisReport:
    findings: List[Finding]
    rules: Tuple[str, ...]
    files: List[str]                    # every file indexed
    strict: bool = False

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def gating(self) -> List[Finding]:
        return gating(self.findings, strict=self.strict)

    @property
    def ok(self) -> bool:
        return not self.gating


def run_analysis(cfg: AnalysisConfig,
                 select: Optional[Sequence[str]] = None,
                 ignore: Optional[Sequence[str]] = None,
                 only_paths: Optional[Sequence[str]] = None,
                 strict: bool = False) -> AnalysisReport:
    rules = cfg.enabled_rules(list(select) if select else None,
                              list(ignore) if ignore else None)
    index = ProjectIndex.build(cfg.root, cfg.paths)
    if only_paths is not None:        # explicit files may sit off cfg.paths
        for p in only_paths:
            fp = Path(p) if Path(p).is_absolute() else Path(cfg.root) / p
            if fp.suffix == ".py" and fp.is_file():
                index.add_file(fp)

    findings: List[Finding] = []
    jax_rules = [r for r in rules if r.startswith("JX")]
    reach = None
    if jax_rules or "SH001" in rules:
        reach = compute_reachable(index)
    if jax_rules:
        findings += check_jax_rules(reach, jax_rules)
    if "PT001" in rules:
        findings += check_pytree_rules(index)
    if "UN001" in rules:
        findings += check_unit_rules(index, cfg)
    if "SC001" in rules:
        findings += check_scan_rules(index)
    if "DN001" in rules:
        findings += check_donation_rules(index)
    if "SH001" in rules:
        findings += check_sharding_rules(index, reach)

    if only_paths is not None:
        keep = {_norm(cfg.root, p) for p in only_paths}
        findings = [f for f in findings if f.path in keep]

    waivers = {mod.path: w for mod in index.modules.values()
               if (w := scan_waivers(mod.source, mod.tree))}
    if only_paths is not None:
        keep = {_norm(cfg.root, p) for p in only_paths}
        waivers = {p: w for p, w in waivers.items() if p in keep}
    findings = apply_waivers(findings, waivers, strict=strict)
    findings = [dataclasses.replace(f, severity=cfg.severity_for(f.code))
                for f in findings]
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return AnalysisReport(findings=findings, rules=rules,
                          files=sorted(index.by_path), strict=strict)


def _norm(root: Path, path: str) -> str:
    p = Path(path)
    if p.is_absolute():
        try:
            return p.resolve().relative_to(Path(root).resolve()).as_posix()
        except ValueError:
            return p.as_posix()
    return p.as_posix()


def changed_files(root: Path, base: str = "main") -> List[str]:
    """Python files changed vs ``base`` (plus any uncommitted edits).

    Rename-aware: ``git diff --name-status -M`` reports ``R<score>`` rows
    with both paths — a pure rename (``R100``) is content-identical to a
    file the base already linted, so it is skipped entirely; a rename with
    edits lints the *new* path.  Deletions never lint.
    """
    out: set = set()
    for args in (["git", "diff", "--name-status", "-M", f"{base}...HEAD"],
                 ["git", "diff", "--name-status", "-M", "HEAD"]):
        try:
            proc = subprocess.run(args, cwd=root, capture_output=True,
                                  text=True, check=False)
        except OSError:
            continue
        if proc.returncode != 0:
            continue
        out.update(_parse_name_status(proc.stdout))
    try:
        proc = subprocess.run(
            ["git", "ls-files", "--others", "--exclude-standard"],
            cwd=root, capture_output=True, text=True, check=False)
        if proc.returncode == 0:
            out.update(line.strip() for line in proc.stdout.splitlines()
                       if line.strip().endswith(".py"))
    except OSError:
        pass
    return sorted(out)


def _parse_name_status(text: str) -> List[str]:
    """``--name-status -M`` rows -> paths to lint (see changed_files)."""
    paths: List[str] = []
    for line in text.splitlines():
        parts = line.rstrip("\n").split("\t")
        if not parts or not parts[0]:
            continue
        status = parts[0]
        if status.startswith("D"):
            continue
        if status.startswith(("R", "C")):
            if len(parts) < 3:
                continue
            if status in ("R100", "C100"):
                continue                 # content-identical to the base
            path = parts[2]              # the new path carries the edits
        else:
            path = parts[-1]
        if path.endswith(".py"):
            paths.append(path)
    return paths
