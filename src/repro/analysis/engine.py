"""Orchestrator: index → reachability → rules → waivers → report.

The full project index is always built (even under ``--changed``) so that
cross-module jit reachability and import resolution stay whole-program;
``only_paths`` then filters which files may *report* findings.  Nothing in
the audited tree is imported — see :mod:`repro.analysis.project`.
"""
from __future__ import annotations

import dataclasses
import subprocess
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from .config import AnalysisConfig
from .findings import Finding, apply_waivers, scan_waivers
from .project import ProjectIndex
from .reachability import compute_reachable
from .rules_jax import check_jax_rules
from .rules_pytree import check_pytree_rules
from .rules_units import check_unit_rules


@dataclasses.dataclass
class AnalysisReport:
    findings: List[Finding]
    rules: Tuple[str, ...]
    files: List[str]                    # every file indexed

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def ok(self) -> bool:
        return not self.active


def run_analysis(cfg: AnalysisConfig,
                 select: Optional[Sequence[str]] = None,
                 ignore: Optional[Sequence[str]] = None,
                 only_paths: Optional[Sequence[str]] = None,
                 strict: bool = False) -> AnalysisReport:
    rules = cfg.enabled_rules(list(select) if select else None,
                              list(ignore) if ignore else None)
    index = ProjectIndex.build(cfg.root, cfg.paths)
    if only_paths is not None:        # explicit files may sit off cfg.paths
        for p in only_paths:
            fp = Path(p) if Path(p).is_absolute() else Path(cfg.root) / p
            if fp.suffix == ".py" and fp.is_file():
                index.add_file(fp)

    findings: List[Finding] = []
    jax_rules = [r for r in rules if r.startswith("JX")]
    if jax_rules:
        findings += check_jax_rules(compute_reachable(index), jax_rules)
    if "PT001" in rules:
        findings += check_pytree_rules(index)
    if "UN001" in rules:
        findings += check_unit_rules(index, cfg)

    if only_paths is not None:
        keep = {_norm(cfg.root, p) for p in only_paths}
        findings = [f for f in findings if f.path in keep]

    waivers = {mod.path: w for mod in index.modules.values()
               if (w := scan_waivers(mod.source))}
    if only_paths is not None:
        keep = {_norm(cfg.root, p) for p in only_paths}
        waivers = {p: w for p, w in waivers.items() if p in keep}
    findings = apply_waivers(findings, waivers, strict=strict)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return AnalysisReport(findings=findings, rules=rules,
                          files=sorted(index.by_path))


def _norm(root: Path, path: str) -> str:
    p = Path(path)
    if p.is_absolute():
        try:
            return p.resolve().relative_to(Path(root).resolve()).as_posix()
        except ValueError:
            return p.as_posix()
    return p.as_posix()


def changed_files(root: Path, base: str = "main") -> List[str]:
    """Python files changed vs ``base`` (plus any uncommitted edits)."""
    out: set = set()
    for args in (["git", "diff", "--name-only", f"{base}...HEAD"],
                 ["git", "diff", "--name-only", "HEAD"],
                 ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            proc = subprocess.run(args, cwd=root, capture_output=True,
                                  text=True, check=False)
        except OSError:
            continue
        if proc.returncode != 0:
            continue
        out.update(line.strip() for line in proc.stdout.splitlines()
                   if line.strip().endswith(".py"))
    return sorted(out)
