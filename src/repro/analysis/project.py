"""AST project index — parse once, resolve imports, never import the target.

The lint rules run on syntax trees alone (``ast.parse``), so auditing
``repro.core.simkernel_jax`` does not execute it (no JAX import, no device
init).  :class:`ProjectIndex` maps every ``.py`` file under the configured
paths to a :class:`ModuleInfo` carrying its tree, import aliases resolved to
dotted module names, top-level functions/classes, and a child->parent node
map (rules climb it to find the enclosing function of a call site).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple


@dataclasses.dataclass
class ModuleInfo:
    path: str                               # repo-relative, posix
    module: str                             # dotted name, e.g. repro.core.dvfs
    tree: ast.Module
    source: str
    imports: Dict[str, str]                 # alias -> dotted module
    from_imports: Dict[str, Tuple[str, str]]  # name -> (module, original)
    functions: Dict[str, ast.FunctionDef]   # top-level defs
    classes: Dict[str, ast.ClassDef]        # top-level classes
    global_names: frozenset                 # module-level assigned names
    parents: Dict[ast.AST, ast.AST]         # child -> parent

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self.parents.get(cur)
        return None


def module_name_for(relpath: Path) -> str:
    """Dotted module name for a repo-relative path (``src`` layout aware)."""
    parts = list(relpath.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _resolve_relative(module: str, level: int, target: Optional[str]) -> str:
    """Resolve ``from ..x import y`` against the importing module's package."""
    base = module.split(".")
    # the module itself is not a package (no __init__ handling needed for
    # lint purposes): level=1 -> its package, each extra level climbs one
    base = base[:-level] if level <= len(base) else []
    if target:
        base += target.split(".")
    return ".".join(base)


class ProjectIndex:
    """All parsed modules, addressable by dotted name or path."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self.modules: Dict[str, ModuleInfo] = {}
        self.by_path: Dict[str, ModuleInfo] = {}

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, root: Path, paths: Iterable[str],
              exclude: Tuple[str, ...] = ("tests/fixtures",)) -> \
            "ProjectIndex":
        idx = cls(root)
        for files_root in paths:
            base = (Path(root) / files_root).resolve()
            if base.is_file():
                idx.add_file(base)
                continue
            if not base.is_dir():
                continue
            for py in sorted(base.rglob("*.py")):
                rel = py.relative_to(root).as_posix()
                if any(rel.startswith(e) for e in exclude):
                    continue
                idx.add_file(py)
        return idx

    def add_file(self, path: Path) -> Optional[ModuleInfo]:
        path = Path(path).resolve()
        try:
            rel = path.relative_to(self.root).as_posix()
        except ValueError:
            rel = path.as_posix()
        if rel in self.by_path:
            return self.by_path[rel]
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError:
            return None
        mod = _build_module(rel, tree, source)
        self.modules[mod.module] = mod
        self.by_path[rel] = mod
        return mod

    # -- resolution ---------------------------------------------------------
    def resolve_module(self, dotted: str) -> Optional[ModuleInfo]:
        return self.modules.get(dotted)

    def resolve_function(self, dotted: str) -> \
            Optional[Tuple[ModuleInfo, ast.FunctionDef]]:
        """``repro.core.dvfs.ondemand_index`` -> (module, def) if indexed."""
        if "." not in dotted:
            return None
        mod_name, func = dotted.rsplit(".", 1)
        mod = self.modules.get(mod_name)
        if mod is not None and func in mod.functions:
            return mod, mod.functions[func]
        return None


def _build_module(rel: str, tree: ast.Module, source: str) -> ModuleInfo:
    module = module_name_for(Path(rel))
    imports: Dict[str, str] = {}
    from_imports: Dict[str, Tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
                if a.asname:
                    imports[a.asname] = a.name
        elif isinstance(node, ast.ImportFrom):
            src = node.module or ""
            if node.level:
                src = _resolve_relative(module, node.level, node.module)
            for a in node.names:
                if a.name == "*":
                    continue
                from_imports[a.asname or a.name] = (src, a.name)

    functions = {n.name: n for n in tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    classes = {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}

    global_names = set()
    for n in tree.body:
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    global_names.add(t.id)
        elif isinstance(n, (ast.AnnAssign, ast.AugAssign)) and \
                isinstance(n.target, ast.Name):
            global_names.add(n.target.id)

    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent

    return ModuleInfo(path=rel, module=module, tree=tree, source=source,
                      imports=imports, from_imports=from_imports,
                      functions=functions, classes=classes,
                      global_names=frozenset(global_names), parents=parents)


# --------------------------------------------------------------------------
# Dotted-name resolution of expressions (through the import aliases)
# --------------------------------------------------------------------------

#: well-known aliases normalised even without seeing the import (defensive:
#: fixtures and repos conventionally use these spellings)
_CANON = {"jnp": "jax.numpy", "np": "numpy"}


def dotted_name(node: ast.AST, mod: ModuleInfo) -> Optional[str]:
    """Resolve ``Name``/``Attribute`` chains to a dotted path.

    ``jnp.where`` -> ``jax.numpy.where``; ``_thermal.exact_step_jax`` ->
    ``repro.core.thermal.exact_step_jax``; ``ondemand_index`` (from-import)
    -> ``repro.core.dvfs.ondemand_index``; plain local names resolve to
    ``<module>.<name>`` when the module defines them at top level.
    """
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    head = cur.id
    parts.reverse()
    if head in mod.from_imports:
        src, orig = mod.from_imports[head]
        base = f"{src}.{orig}" if src else orig
    elif head in mod.imports:
        base = mod.imports[head]
    elif head in mod.functions or head in mod.classes or \
            head in mod.global_names:
        base = f"{mod.module}.{head}" if mod.module else head
    else:
        base = head
    first = base.split(".")[0]
    if first in _CANON:
        base = ".".join([_CANON[first]] + base.split(".")[1:])
    return ".".join([base] + parts) if parts else base
