"""``python -m repro.analysis`` — the JAX-contract lint CLI.

Examples::

    python -m repro.analysis                      # lint configured paths
    python -m repro.analysis --strict             # warns gate, waivers need a reason
    python -m repro.analysis --changed            # only files vs main
    python -m repro.analysis --select JX001,JX003
    python -m repro.analysis --report findings.json
    python -m repro.analysis --sarif findings.sarif
    python -m repro.analysis --format sarif       # SARIF log on stdout
    python -m repro.analysis --fix                # apply UN001 renames
    python -m repro.analysis --compile-gate BENCH_*.json
    python -m repro.analysis --list-rules

Exit status: 0 when no *gating* findings, 1 otherwise, 2 on usage errors.
A finding gates per its severity: ``error`` always, ``warn`` only under
``--strict`` (the CI mode), ``info`` never.  Waived findings print with a
``(waived)`` tag and never gate; ``--strict`` additionally requires every
waiver to carry a ``-- justification`` (WV001).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .compile_gate import check_compile_gate
from .config import ALL_RULES, DEFAULT_SEVERITY, RULE_DOCS, load_config
from .engine import changed_files, run_analysis
from .findings import dump_report, render_report
from .sarif import dump_sarif, render_sarif


def _codes(arg: Optional[str]) -> Optional[List[str]]:
    if not arg:
        return None
    return [c.strip().upper() for c in arg.split(",") if c.strip()]


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static JAX-contract lints + compile-count gate "
                    "(DESIGN.md §12)")
    ap.add_argument("files", nargs="*",
                    help="explicit files to lint (default: configured "
                         "paths)")
    ap.add_argument("--strict", action="store_true",
                    help="warn-severity findings gate; waivers must carry "
                         "a justification (WV001)")
    ap.add_argument("--changed", action="store_true",
                    help="lint only files changed vs --base")
    ap.add_argument("--base", default="main",
                    help="git base ref for --changed (default: main)")
    ap.add_argument("--select", metavar="CODES",
                    help="comma-separated rule codes to run exclusively")
    ap.add_argument("--ignore", metavar="CODES",
                    help="comma-separated rule codes to skip")
    ap.add_argument("--report", metavar="PATH",
                    help="write the findings report JSON (CI artifact)")
    ap.add_argument("--sarif", metavar="PATH",
                    help="write a SARIF 2.1.0 log (CI code-scanning "
                         "upload)")
    ap.add_argument("--format", choices=("text", "sarif"), default="text",
                    help="stdout format (default: text)")
    ap.add_argument("--fix", action="store_true",
                    help="mechanically apply UN001 unit-suffix renames "
                         "(definition + call sites), then re-lint")
    ap.add_argument("--root", metavar="DIR", default=None,
                    help="repo root (default: nearest pyproject.toml)")
    ap.add_argument("--compile-gate", nargs="+", metavar="BENCH_JSON",
                    help="run only the CC001 gate over these bench "
                         "artifacts")
    ap.add_argument("--contracts", metavar="PATH", default=None,
                    help="contracts.json for --compile-gate (default: "
                         "from [tool.repro.analysis])")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule codes, severities and summaries")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in (*ALL_RULES, "WV001"):
            level = DEFAULT_SEVERITY.get(code, "error")
            print(f"{code}  [{level:5s}]  {RULE_DOCS[code]}")
        return 0

    cfg = load_config(Path(args.root) if args.root else None)

    if args.compile_gate:
        contracts = Path(args.contracts) if args.contracts \
            else cfg.root / cfg.contracts
        try:
            findings = check_compile_gate(contracts, args.compile_gate)
        except (OSError, ValueError, KeyError) as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        if findings:
            print(render_report(findings))
            if args.report:
                dump_report(findings, args.report, rules=["CC001"])
            if args.sarif:
                dump_sarif(findings, args.sarif)
            return 1
        print(f"CC001: {len(args.compile_gate)} bench artifact(s) within "
              f"contract ({contracts})")
        if args.report:
            dump_report([], args.report, rules=["CC001"])
        if args.sarif:
            dump_sarif([], args.sarif)
        return 0

    only: Optional[List[str]] = None
    if args.changed:
        only = changed_files(cfg.root, args.base)
        lintable = {p for p in only
                    if any(p.startswith(base) for base in cfg.paths)}
        if not lintable:
            print(f"--changed: no lintable files vs {args.base}")
            return 0
        only = sorted(lintable)
    elif args.files:
        only = args.files

    if args.fix:
        from .fix import apply_fixes, plan_fixes
        from .project import ProjectIndex
        index = ProjectIndex.build(cfg.root, cfg.paths)
        result = apply_fixes(cfg.root, plan_fixes(index, cfg))
        for note in result.skipped:
            print(f"fix: skipped {note}")
        print(f"fix: applied {result.applied} edit(s) across "
              f"{len(result.files)} file(s)")
        # fall through and re-lint the rewritten tree

    try:
        report = run_analysis(cfg, select=_codes(args.select),
                              ignore=_codes(args.ignore),
                              only_paths=only, strict=args.strict)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.report:
        dump_report(report.findings, args.report, rules=list(report.rules),
                    files=report.files)
    if args.sarif:
        dump_sarif(report.findings, args.sarif)
    if args.format == "sarif":
        print(render_sarif(report.findings))
    elif report.findings:
        print(render_report(report.findings))
    else:
        scope = f"{len(only)} changed/selected file(s)" if only \
            else f"{len(report.files)} file(s)"
        print(f"clean: {scope}, rules {','.join(report.rules)}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
