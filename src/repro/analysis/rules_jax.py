"""JX001–JX003: JAX tracing contracts on the jit-reachable set.

* **JX001 tracer-leak** — ``.item()`` / ``.tolist()``, ``bool()/int()/
  float()`` on traced values, and ``if``/``while`` branching on array
  expressions: all raise ``ConcretizationTypeError`` (or silently constant-
  fold) under ``jax.jit``.
* **JX002 host-numpy-in-jit** — ``np.*`` calls fed traced data inside jitted
  code pull the value to host per call (or fail to trace); use ``jnp``.
* **JX003 impure-jit** — side effects in a jitted python body run once per
  *compile*, not per call: printing, wall-clock reads, host RNG, ``global``
  / ``self`` mutation and module-global mutation are almost always bugs (the
  deliberate compile-counter exception carries a waiver).

Whether a value is "traced" is approximated by taint: function parameters
(minus every name seen in a ``static_argnames``) and anything assigned from
them or from a ``jax.*`` call.  Host-side numpy on *constants* at trace time
is idiomatic constant folding and stays clean.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from .findings import Finding
from .project import ModuleInfo, dotted_name
from .reachability import ReachableSet, Unit

_HOST_CASTS = ("bool", "int", "float")
_SHAPE_SAFE_ATTRS = {"shape", "ndim", "dtype", "size"}
_LEAK_METHODS = {"item", "tolist"}
_TIME_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
               "time.process_time", "time.sleep", "time.monotonic_ns",
               "time.perf_counter_ns"}
_GLOBAL_MUTATORS = {"inc", "dec", "append", "add", "update", "extend",
                    "insert", "remove", "clear", "setdefault", "pop",
                    "reset"}


#: parameter annotations that mark a *host* value even inside jitted code:
#: python scalars are static under jit, and the repo's ``*Config`` /
#: ``*Spec`` dataclasses carry static hyperparameters (their pytree
#: registrations put every field in ``meta_fields``)
_STATIC_ANNOTATIONS = {"int", "bool", "str"}


def _params(node: ast.AST) -> List[str]:
    a = node.args
    names = []
    for p in (a.posonlyargs + a.args + a.kwonlyargs):
        ann = getattr(p, "annotation", None)
        if ann is not None:
            src = ast.unparse(ann)
            if src in _STATIC_ANNOTATIONS or src.endswith("Config") \
                    or src.endswith("Spec"):
                continue
        names.append(p.arg)
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _taint(unit: Unit, static_names: frozenset) -> Set[str]:
    """Names plausibly bound to traced arrays inside the unit's subtree."""
    tainted: Set[str] = set()
    for node in ast.walk(unit.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            tainted.update(p for p in _params(node)
                           if p not in static_names)

    def refs_taint(expr: ast.AST) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and n.id in tainted:
                return True
            if isinstance(n, ast.Call):
                d = dotted_name(n.func, unit.mod) or ""
                if d.startswith("jax."):
                    return True
        return False

    stmts = [n for n in ast.walk(unit.node)
             if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                               ast.For, ast.withitem))]
    stmts.sort(key=lambda n: getattr(n, "lineno", 0))
    for _ in range(2):                       # cheap fixpoint, 2 passes
        for st in stmts:
            if isinstance(st, ast.For):
                src, dsts = st.iter, [st.target]
            elif isinstance(st, ast.withitem):
                src = st.context_expr
                dsts = [st.optional_vars] if st.optional_vars else []
            else:
                src = st.value
                dsts = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
            if src is None or not refs_taint(src):
                continue
            for d in dsts:
                for n in ast.walk(d):
                    if isinstance(n, ast.Name):
                        tainted.add(n.id)
    return tainted


def _is_tainted(expr: ast.AST, tainted: Set[str], mod: ModuleInfo) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Name) and n.id in tainted:
            return True
        if isinstance(n, ast.Call):
            d = dotted_name(n.func, mod) or ""
            if d.startswith("jax."):
                return True
    return False


def _shape_safe(expr: ast.AST) -> bool:
    """True when the expression reads static metadata (shape/ndim/len)."""
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and n.attr in _SHAPE_SAFE_ATTRS:
            return True
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                and n.func.id == "len":
            return True
    return False


def _jax_call_in(expr: ast.AST, mod: ModuleInfo) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            d = dotted_name(n.func, mod) or ""
            if d.startswith("jax."):
                return True
    return False


def check_jax_rules(reachable: ReachableSet,
                    rules: Iterable[str]) -> List[Finding]:
    rules = set(rules)
    raw: List[Finding] = []
    for unit in reachable:
        tainted = _taint(unit, reachable.static_param_names)
        raw.extend(_check_unit(unit, tainted, rules))
    # nested defs can appear both inside a parent unit and as their own
    # root — report each site once
    seen, out = set(), []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.code)):
        key = (f.code, f.path, f.line, f.col)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def _check_unit(unit: Unit, tainted: Set[str],
                rules: Set[str]) -> List[Finding]:
    mod, out = unit.mod, []

    def emit(code: str, node: ast.AST, msg: str) -> None:
        if code in rules:
            out.append(Finding(code=code, path=mod.path, line=node.lineno,
                               col=node.col_offset,
                               message=f"{msg} [jit-reachable via "
                                       f"`{unit.name}`]"))

    for node in ast.walk(unit.node):
        # -- JX001: tracer leaks ------------------------------------------
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _LEAK_METHODS \
                    and _is_tainted(node.func.value, tainted, mod):
                emit("JX001", node,
                     f"`.{node.func.attr}()` on a traced value pulls it to "
                     f"host (ConcretizationTypeError under jit)")
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in _HOST_CASTS and node.args \
                    and _is_tainted(node.args[0], tainted, mod) \
                    and not _shape_safe(node.args[0]):
                emit("JX001", node,
                     f"`{node.func.id}()` on a traced value concretizes it; "
                     f"keep it an array (jnp) or hoist out of the jit")
        if isinstance(node, (ast.If, ast.While)) \
                and _jax_call_in(node.test, mod):
            kw = "if" if isinstance(node, ast.If) else "while"
            emit("JX001", node,
                 f"`{kw}` on an array expression branches on a traced "
                 f"value; use jnp.where / lax.cond / lax.while_loop")

        # -- JX002: host numpy on traced data -----------------------------
        if isinstance(node, ast.Call):
            d = dotted_name(node.func, mod) or ""
            if d.startswith("numpy.") and not d.startswith("numpy.random.") \
                    and any(_is_tainted(a, tainted, mod)
                            for a in list(node.args)
                            + [k.value for k in node.keywords]):
                emit("JX002", node,
                     f"host numpy call `{d}` on traced data inside jitted "
                     f"code; use the jnp equivalent")

        # -- JX003: impurity ----------------------------------------------
        if isinstance(node, ast.Call):
            d = dotted_name(node.func, mod) or ""
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                emit("JX003", node,
                     "`print` in a jitted body runs only on trace; use "
                     "jax.debug.print for per-call output")
            elif d in _TIME_CALLS:
                emit("JX003", node,
                     f"wall-clock read `{d}` inside jitted code executes "
                     f"once per compile, not per call")
            elif d.startswith("numpy.random.") or d.startswith("random."):
                emit("JX003", node,
                     f"host RNG `{d}` inside jitted code is baked in at "
                     f"trace time; thread a jax.random key instead")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _GLOBAL_MUTATORS \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id in mod.global_names:
                emit("JX003", node,
                     f"mutation of module global "
                     f"`{node.func.value.id}.{node.func.attr}()` inside "
                     f"jitted code happens per compile, not per call")
        if isinstance(node, ast.Global):
            emit("JX003", node,
                 f"`global {', '.join(node.names)}` inside jitted code: "
                 f"writes happen per compile, not per call")
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    emit("JX003", node,
                         f"`self.{t.attr} = …` inside jitted code mutates "
                         f"state per compile, not per call")
                elif isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id in mod.global_names:
                    emit("JX003", node,
                         f"subscript write to module global "
                         f"`{t.value.id}[…]` inside jitted code happens "
                         f"per compile, not per call")
    return out
