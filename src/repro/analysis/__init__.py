"""repro.analysis — static JAX-contract lints + compile-count gate.

Pure-AST rules (the audited modules are never imported, so linting the JAX
kernels costs no device init and works without jax installed):

* **JX001** tracer-leak, **JX002** host-numpy-in-jit, **JX003** impure-jit
  — on the jit-reachable set (:mod:`.reachability`)
* **PT001** pytree registration contracts
* **UN001** unit-suffix discipline on result structs (``--fix`` can apply
  the suggested renames mechanically, :mod:`.fix`)
* **SC001** scan-carry stability (arity / order / dtype across loop bodies)
* **DN001** use-after-donate on jit call sites
* **SH001** lane-sharding contracts (leading-axis PartitionSpec, no
  device_put / mesh construction under trace)
* **CC001** compile-count regression gate over ``BENCH_*.json`` artifacts

Findings carry a severity (``error``/``warn``/``info``; per-rule overrides
in config): ``error`` gates every run, ``warn`` gates under ``--strict``,
``info`` never.  Reports emit as text, JSON, or SARIF 2.1.0
(:mod:`.sarif`) for CI code-scanning annotations.

CLI: ``python -m repro.analysis`` (see ``--help``); config lives in the
``[tool.repro.analysis]`` table of ``pyproject.toml``; inline waivers are
``# lint: waive CODE -- justification``.  DESIGN.md §12 documents the
rules and the waiver policy.
"""
from .config import (ALL_RULES, DEFAULT_SEVERITY, RULE_DOCS, AnalysisConfig,
                     load_config)
from .engine import AnalysisReport, changed_files, run_analysis
from .findings import Finding, gating, render_report, report_payload
from .compile_gate import check_compile_gate, load_contracts
from .sarif import render_sarif, sarif_payload

__all__ = [
    "ALL_RULES", "AnalysisConfig", "AnalysisReport", "DEFAULT_SEVERITY",
    "Finding", "RULE_DOCS", "changed_files", "check_compile_gate", "gating",
    "load_config", "load_contracts", "render_report", "render_sarif",
    "report_payload", "run_analysis", "sarif_payload",
]
