"""repro.analysis — static JAX-contract lints + compile-count gate.

Pure-AST rules (the audited modules are never imported, so linting the JAX
kernels costs no device init and works without jax installed):

* **JX001** tracer-leak, **JX002** host-numpy-in-jit, **JX003** impure-jit
  — on the jit-reachable set (:mod:`.reachability`)
* **PT001** pytree registration contracts
* **UN001** unit-suffix discipline on result structs
* **CC001** compile-count regression gate over ``BENCH_*.json`` artifacts

CLI: ``python -m repro.analysis`` (see ``--help``); config lives in the
``[tool.repro.analysis]`` table of ``pyproject.toml``; inline waivers are
``# lint: waive CODE -- justification``.  DESIGN.md §12 documents the
rules and the waiver policy.
"""
from .config import ALL_RULES, AnalysisConfig, load_config
from .engine import AnalysisReport, changed_files, run_analysis
from .findings import Finding, render_report, report_payload
from .compile_gate import check_compile_gate, load_contracts

__all__ = [
    "ALL_RULES", "AnalysisConfig", "AnalysisReport", "Finding",
    "changed_files", "check_compile_gate", "load_config", "load_contracts",
    "render_report", "report_payload", "run_analysis",
]
