"""SC001 — scan-carry stability across ``lax.scan``/``while_loop``/``fori_loop``.

A loop body traced by JAX must return a carry with the *same pytree
structure and dtypes* as the one it received, or tracing fails with an
opaque structure-mismatch error — and some divergences (weak-type
promotion) slip through tracing only to recompile per call.  The exact bug
classes the §7 epoch scan and §11 telemetry replay are hand-audited
against are checked statically here:

* **arity** — the body unpacks an N-tuple carry (or the call site's init is
  an N-tuple literal) but returns an M-tuple carry, M ≠ N; a ``lax.scan``
  body returning anything but a ``(carry, ys)`` pair is the degenerate
  case.
* **element order** — the returned carry tuple is exactly the unpacked
  carry names in a different order: structure-compatible, silently wrong.
* **dtype** — an integer-initialised carry element flows through ``/``
  (true division) or ``jnp.mean`` (both promote to float), or an
  ``astype`` whose target dtype-kind differs from the init literal's;
  with multiple ``return`` statements, an ``astype`` applied on one path
  but not another.

Everything is best-effort pure AST: carries that are dicts, dataclasses or
opaque call results are skipped, never guessed at.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .findings import Finding
from .project import ModuleInfo, ProjectIndex, dotted_name

#: canonical loop entry -> (body argument position, init argument position)
_LOOP_CALLS: Dict[str, Tuple[int, int]] = {
    "jax.lax.scan": (0, 1),
    "jax.lax.while_loop": (1, 2),
    "jax.lax.fori_loop": (2, 3),
}

#: carry parameter index within the body signature (before partial binding)
_CARRY_PARAM = {"jax.lax.scan": 0, "jax.lax.while_loop": 0,
                "jax.lax.fori_loop": 1}

_MEAN_CALLS = ("jax.numpy.mean", "numpy.mean", "jax.numpy.average",
               "numpy.average")


def check_scan_rules(index: ProjectIndex) -> List[Finding]:
    raw: List[Finding] = []
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = dotted_name(node.func, mod)
            spec = _LOOP_CALLS.get(dotted or "")
            if spec is None:
                continue
            body_pos, init_pos = spec
            if body_pos >= len(node.args):
                continue
            init = node.args[init_pos] if init_pos < len(node.args) else None
            for body_mod, fn, bound in _resolve_body(
                    node.args[body_pos], mod, index):
                raw.extend(_check_body(dotted, node, init, mod,
                                       body_mod, fn, bound))
    seen, out = set(), []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.message)):
        key = (f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


# -- body resolution ---------------------------------------------------------

def _resolve_body(expr: ast.AST, mod: ModuleInfo, index: ProjectIndex,
                  bound: int = 0) -> List[Tuple[ModuleInfo, ast.AST, int]]:
    """Resolve a loop-body expression to ``(module, fn node, bound args)``.

    ``functools.partial(f, a, b)`` shifts the carry parameter right by the
    number of bound positional arguments.
    """
    if isinstance(expr, ast.Lambda):
        return [(mod, expr, bound)]
    if isinstance(expr, ast.Call):
        if dotted_name(expr.func, mod) == "functools.partial" and expr.args:
            return _resolve_body(expr.args[0], mod, index,
                                 bound + len(expr.args) - 1)
        return []
    if isinstance(expr, ast.Name):
        scope = mod.enclosing_function(expr)
        while scope is not None:
            for n in ast.walk(scope):
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and n is not scope and n.name == expr.id:
                    return [(mod, n, bound)]
            scope = mod.enclosing_function(scope)
    if isinstance(expr, (ast.Name, ast.Attribute)):
        dotted = dotted_name(expr, mod)
        if dotted:
            hit = index.resolve_function(dotted)
            if hit is not None:
                return [(hit[0], hit[1], bound)]
    return []


def _walk_own(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested defs/lambdas."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _carry_param_name(fn: ast.AST, kind: str, bound: int) -> Optional[str]:
    args = fn.args
    params = [p.arg for p in (args.posonlyargs + args.args)]
    idx = bound + _CARRY_PARAM[kind]
    return params[idx] if idx < len(params) else None


def _returned_carries(fn: ast.AST, kind: str, display: str,
                      mod: ModuleInfo) -> Tuple[List[Finding],
                                                List[Tuple[int, ast.AST]]]:
    """``(pair findings, [(line, carry expr)])`` per ``return`` statement."""
    findings: List[Finding] = []
    carries: List[Tuple[int, ast.AST]] = []
    if isinstance(fn, ast.Lambda):
        values: List[Tuple[int, ast.AST]] = [(fn.body.lineno, fn.body)]
    else:
        values = [(n.lineno, n.value) for n in _walk_own(fn)
                  if isinstance(n, ast.Return) and n.value is not None]
    for line, value in values:
        if kind == "jax.lax.scan":
            if not isinstance(value, ast.Tuple):
                continue                       # opaque pair: nothing to check
            if len(value.elts) != 2:
                findings.append(Finding(
                    code="SC001", path=mod.path, line=line,
                    col=value.col_offset,
                    message=f"scan body `{display}` must return a "
                            f"(carry, ys) pair; got a "
                            f"{len(value.elts)}-tuple"))
                continue
            carries.append((line, value.elts[0]))
        else:
            carries.append((line, value))
    return findings, carries


# -- dtype classification ----------------------------------------------------

def _dtype_kind_of_name(dotted: Optional[str]) -> Optional[str]:
    """``jax.numpy.int32`` -> "int", ``numpy.float32`` -> "float", …"""
    if not dotted:
        return None
    leaf = dotted.rsplit(".", 1)[-1]
    if leaf.startswith(("int", "uint")) or leaf == "bool_":
        return "int"
    if leaf.startswith(("float", "bfloat", "half", "double")):
        return "float"
    return None


def _init_kind(expr: Optional[ast.AST], mod: ModuleInfo) -> Optional[str]:
    """Best-effort dtype kind ("int"/"float") of an init-literal element."""
    if expr is None:
        return None
    if isinstance(expr, ast.Constant):
        if isinstance(expr.value, bool):
            return None
        if isinstance(expr.value, int):
            return "int"
        if isinstance(expr.value, float):
            return "float"
        return None
    if isinstance(expr, ast.UnaryOp):
        return _init_kind(expr.operand, mod)
    if not isinstance(expr, ast.Call):
        return None
    dotted = dotted_name(expr.func, mod) or ""
    kind = _dtype_kind_of_name(dotted)
    if kind is not None:                     # jnp.int32(0), np.float32(x)
        return kind
    leaf = dotted.rsplit(".", 1)[-1]
    if dotted.startswith(("jax.numpy.", "numpy.")) and \
            leaf in ("zeros", "ones", "full", "asarray", "array",
                     "full_like", "zeros_like", "ones_like", "empty"):
        dt = None
        for kw in expr.keywords:
            if kw.arg == "dtype":
                dt = kw.value
        if dt is None and leaf in ("zeros", "ones", "full", "asarray",
                                   "array") and len(expr.args) >= 2:
            cand = expr.args[-1]
            if _dtype_kind_of_name(dotted_name(cand, mod)):
                dt = cand
        if dt is not None:
            return _dtype_kind_of_name(dotted_name(dt, mod))
        return "float" if leaf in ("zeros", "ones", "empty") else None
    if dotted in ("jax.numpy.arange", "numpy.arange"):
        for kw in expr.keywords:
            if kw.arg == "dtype":
                return _dtype_kind_of_name(dotted_name(kw.value, mod))
        if all(isinstance(a, ast.Constant) and isinstance(a.value, int)
               for a in expr.args):
            return "int"
    return None


def _names_in(expr: ast.AST) -> set:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def _has_true_div(expr: ast.AST, names: set) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Div):
            if not names or (_names_in(n) & names):
                return True
    return False


def _mean_call(expr: ast.AST, mod: ModuleInfo) -> Optional[str]:
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            d = dotted_name(n.func, mod)
            if d in _MEAN_CALLS:
                return d
    return None


def _astype_target(expr: ast.AST, mod: ModuleInfo) -> Optional[str]:
    """Dtype kind of a top-level ``<x>.astype(T)`` expression, "" unknown."""
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Attribute) \
            and expr.func.attr == "astype" and expr.args:
        return _dtype_kind_of_name(dotted_name(expr.args[0], mod)) or ""
    return None


# -- the per-body check ------------------------------------------------------

def _check_body(kind: str, call: ast.Call, init: Optional[ast.AST],
                call_mod: ModuleInfo, body_mod: ModuleInfo, fn: ast.AST,
                bound: int) -> List[Finding]:
    out: List[Finding] = []
    display = fn.name if isinstance(fn, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
        else f"<lambda:L{fn.lineno}>"

    pair_findings, carries = _returned_carries(fn, kind, display, body_mod)
    out.extend(pair_findings)

    carry_name = _carry_param_name(fn, kind, bound)

    # input carry shape: the body's own tuple unpack wins, else the call
    # site's init literal
    unpack_names: Optional[List[str]] = None
    if carry_name is not None and not isinstance(fn, ast.Lambda):
        for n in _walk_own(fn):
            if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                    and isinstance(n.targets[0], ast.Tuple) \
                    and isinstance(n.value, ast.Name) \
                    and n.value.id == carry_name:
                elts = n.targets[0].elts
                if all(isinstance(e, ast.Name) for e in elts):
                    unpack_names = [e.id for e in elts]
                break

    init_elts: Optional[Sequence[ast.AST]] = None
    if isinstance(init, (ast.Tuple, ast.List)):
        init_elts = init.elts
    in_arity = len(unpack_names) if unpack_names is not None else \
        (len(init_elts) if init_elts is not None else None)

    def emit(line: int, col: int, msg: str) -> None:
        out.append(Finding(code="SC001", path=body_mod.path, line=line,
                           col=col, message=msg))

    astype_by_pos: Dict[int, set] = {}
    for line, carry in carries:
        if not isinstance(carry, ast.Tuple):
            # single-leaf carry: dtype checks against a non-tuple init
            if init_elts is None and in_arity is None:
                _check_elt_dtype(kind, display, carry_name, init, carry,
                                 line, body_mod, call_mod, None, emit)
            continue
        elts = carry.elts
        if in_arity is not None and len(elts) != in_arity:
            src = "unpacked in the body" if unpack_names is not None \
                else "initialised at the call site"
            emit(line, carry.col_offset,
                 f"loop body `{display}` carry arity diverges: "
                 f"{in_arity} element(s) {src}, {len(elts)} returned — "
                 f"the carry pytree must be stable across iterations")
            continue
        if unpack_names is not None \
                and all(isinstance(e, ast.Name) for e in elts):
            ret_names = [e.id for e in elts]
            if ret_names != unpack_names and \
                    sorted(ret_names) == sorted(unpack_names):
                emit(line, carry.col_offset,
                     f"loop body `{display}` returns the carry elements "
                     f"reordered ({', '.join(ret_names)}) vs the input "
                     f"unpack ({', '.join(unpack_names)})")
        for i, e in enumerate(elts):
            at = _astype_target(e, body_mod)
            if at is not None:
                astype_by_pos.setdefault(i, set()).add(line)
            init_e = init_elts[i] if init_elts is not None and \
                i < len(init_elts) else None
            name = unpack_names[i] if unpack_names is not None else None
            _check_elt_dtype(kind, display, name, init_e, e, line,
                             body_mod, call_mod, i, emit)

    # an astype applied on one return path but not the other(s) diverges the
    # carry dtype between branches
    n_tuple_returns = sum(1 for _, c in carries if isinstance(c, ast.Tuple))
    if n_tuple_returns > 1:
        for i, at_lines in sorted(astype_by_pos.items()):
            if len(at_lines) < n_tuple_returns:
                emit(max(at_lines), 0,
                     f"loop body `{display}` applies `.astype` to carry "
                     f"element {i} on {len(at_lines)} of "
                     f"{n_tuple_returns} return paths — the carry dtype "
                     f"diverges between branches")
    return out


def _check_elt_dtype(kind: str, display: str, name: Optional[str],
                     init_e: Optional[ast.AST], ret_e: ast.AST, line: int,
                     body_mod: ModuleInfo, call_mod: ModuleInfo,
                     pos: Optional[int], emit) -> None:
    init_kind = _init_kind(init_e, call_mod)
    label = f"carry element {pos}" if pos is not None else "the carry"
    who = f" `{name}`" if name else ""
    if init_kind == "int":
        names = {name} if name else set()
        if _has_true_div(ret_e, names):
            emit(line, ret_e.col_offset,
                 f"loop body `{display}`: true division promotes the "
                 f"int-initialised {label}{who} to float — the output "
                 f"carry dtype diverges from the init (use `//` or a "
                 f"float init)")
            return
        mean = _mean_call(ret_e, body_mod)
        if mean is not None:
            emit(line, ret_e.col_offset,
                 f"loop body `{display}`: `{mean}` promotes the "
                 f"int-initialised {label}{who} to float — the output "
                 f"carry dtype diverges from the init")
            return
    at = _astype_target(ret_e, body_mod)
    if at and init_kind is not None and at != init_kind:
        emit(line, ret_e.col_offset,
             f"loop body `{display}`: {label}{who} is returned as "
             f"`.astype(<{at}>)` but initialised {init_kind} at the call "
             f"site — the carry dtype diverges from the init")
