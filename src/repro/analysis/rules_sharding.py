"""SH001 — lane-sharding contracts (DESIGN.md §13).

The sweep's sharded execution has three conventions this rule makes
checkable:

* **leading lane axis** — the stacked ``SimTables``/``GovernorPolicy``
  leaves shard along their *leading* axis (``PartitionSpec("lanes")``); a
  ``PartitionSpec`` that names the lane axis at a non-leading position
  splits a per-lane tensor *inside* a lane, which is never what the
  independent-lane contract means.
* **no ``device_put`` under trace** — ``jax.device_put`` inside a
  jit-reachable body is a host placement op captured into the program; the
  streamer must place chunks *before* entering the compiled program.
* **no mesh construction under trace** — ``jax.sharding.Mesh`` /
  ``jax.make_mesh`` / ``mesh_utils.create_device_mesh`` enumerate devices,
  a host-only effect that silently bakes the tracing machine's topology
  into the compiled program.

The first check is module-wide over the whole index (a wrong
``PartitionSpec`` is wrong wherever it is written); the trace checks run
only over the jit-reachable unit set.  These are placement-convention
heuristics, so SH001 defaults to ``warn`` severity (gates only under
``--strict``).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .findings import Finding
from .project import ModuleInfo, ProjectIndex, dotted_name
from .reachability import ReachableSet

#: the lane axis name used by ``repro.sharding`` (DESIGN.md §13)
LANE_AXIS = "lanes"

_PSPEC = ("jax.sharding.PartitionSpec", "jax.interpreters.pxla.PartitionSpec")
_DEVICE_PUT = ("jax.device_put", "jax.device_put_replicated",
               "jax.device_put_sharded")
_MESH_CTORS = ("jax.sharding.Mesh", "jax.make_mesh",
               "jax.experimental.mesh_utils.create_device_mesh")


def check_sharding_rules(index: ProjectIndex,
                         reach: ReachableSet) -> List[Finding]:
    out: List[Finding] = []
    for mod in index.modules.values():
        out.extend(_check_pspec_literals(mod))
    for unit in reach:
        for node in ast.walk(unit.node):
            if not isinstance(node, ast.Call):
                continue
            dotted = dotted_name(node.func, unit.mod)
            if dotted in _DEVICE_PUT:
                out.append(Finding(
                    code="SH001", path=unit.mod.path, line=node.lineno,
                    col=node.col_offset,
                    message=f"`{dotted}` inside jit-reachable "
                            f"`{unit.name}` — device placement is a host "
                            f"op; place buffers before entering the "
                            f"compiled program"))
            elif dotted in _MESH_CTORS:
                out.append(Finding(
                    code="SH001", path=unit.mod.path, line=node.lineno,
                    col=node.col_offset,
                    message=f"`{dotted}` constructs a mesh inside "
                            f"jit-reachable `{unit.name}` — device "
                            f"enumeration is host-only and bakes the "
                            f"tracing machine's topology into the "
                            f"compiled program"))
    dedup, final = set(), []
    for f in sorted(out, key=lambda f: (f.path, f.line, f.col, f.message)):
        key = (f.path, f.line, f.message)
        if key not in dedup:
            dedup.add(key)
            final.append(f)
    return final


def _check_pspec_literals(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if dotted_name(node.func, mod) not in _PSPEC:
            continue
        pos = _lane_axis_position(node)
        if pos is not None and pos > 0:
            out.append(Finding(
                code="SH001", path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=f"PartitionSpec names the lane axis "
                        f"{LANE_AXIS!r} at position {pos} — stacked lane "
                        f"leaves shard along their leading axis "
                        f"(PartitionSpec({LANE_AXIS!r}), DESIGN.md §13)"))
    return out


def _lane_axis_position(call: ast.Call) -> Optional[int]:
    for i, a in enumerate(call.args):
        if isinstance(a, ast.Constant) and a.value == LANE_AXIS:
            return i
        if isinstance(a, (ast.Tuple, ast.List)):
            for e in a.elts:
                if isinstance(e, ast.Constant) and e.value == LANE_AXIS:
                    return i
    return None
