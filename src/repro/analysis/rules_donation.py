"""DN001 — use-after-donate on jitted call sites.

``jax.jit(..., donate_argnums=/donate_argnames=)`` hands an argument's
buffer to XLA: after the call the Python name still binds a deleted array,
and the first later read raises ``RuntimeError: Array has been deleted`` —
at runtime, on the accelerator, long after the lint-able mistake.  The §13
chunk streamer's donated lane buffers rely on convention ("each chunk is
freshly ``device_put``"); this rule makes the convention checkable.

Pure AST: the rule collects donating callables (decorated defs and
``jax.jit(f, donate_...)`` / ``functools.partial(jax.jit, donate_...)(f)``
assignment forms), maps donated names to positions via the wrapped
function's signature, then flags call sites where a donated bare-``Name``
argument is read again later in the same function body — up to the name's
first rebind, which refreshes the buffer.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .project import ModuleInfo, ProjectIndex, dotted_name

_JIT_CALLS = ("jax.jit", "jax.api.jit", "jax.pjit", "jax.experimental.pjit")


class _Donor:
    """One donating callable: which positions/keywords are donated."""

    def __init__(self, positions: Set[int], names: Set[str]):
        self.positions = positions          # donated positional indices
        self.names = names                  # donated keyword names


def check_donation_rules(index: ProjectIndex) -> List[Finding]:
    donors: Dict[str, _Donor] = {}
    for mod in index.modules.values():
        donors.update(_collect_donors(mod, index))
    out: List[Finding] = []
    for mod in index.modules.values():
        out.extend(_check_calls(mod, donors))
    out.sort(key=lambda f: (f.path, f.line, f.col, f.message))
    return out


# -- donor collection --------------------------------------------------------

def _donation_spec(call: ast.Call, mod: ModuleInfo) -> \
        Optional[Tuple[Set[int], Set[str]]]:
    """``(donate positions, donate names)`` of a jit(...) call, if any."""
    positions: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            for n in _int_items(kw.value):
                positions.add(n)
        elif kw.arg == "donate_argnames":
            for s in _str_items(kw.value):
                names.add(s)
    return (positions, names) if positions or names else None


def _int_items(node: ast.AST) -> List[int]:
    items = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    return [n.value for n in items
            if isinstance(n, ast.Constant) and isinstance(n.value, int)]


def _str_items(node: ast.AST) -> List[str]:
    items = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    return [n.value for n in items
            if isinstance(n, ast.Constant) and isinstance(n.value, str)]


def _positions_for(fn: ast.AST, spec: Tuple[Set[int], Set[str]],
                   bound: int = 0) -> _Donor:
    """Resolve donated argnames to positions via the wrapped signature.

    ``bound`` positional args already supplied by ``functools.partial``
    shift every caller-visible position left by that count.
    """
    positions = {p - bound for p in spec[0] if p >= bound}
    names = set(spec[1])
    params = [p.arg for p in (fn.args.posonlyargs + fn.args.args)]
    for name in spec[1]:
        if name in params:
            pos = params.index(name) - bound
            if pos >= 0:
                positions.add(pos)
    return _Donor(positions, names)


def _collect_donors(mod: ModuleInfo, index: ProjectIndex) -> \
        Dict[str, _Donor]:
    """Map dotted callable name -> donation spec for this module."""
    donors: Dict[str, _Donor] = {}

    def jit_spec(call: ast.Call) -> Optional[Tuple[Set[int], Set[str]]]:
        """Donation spec of ``jax.jit(...)`` or ``partial(jax.jit, ...)``."""
        head = dotted_name(call.func, mod)
        if head in _JIT_CALLS:
            return _donation_spec(call, mod)
        if head == "functools.partial" and call.args and \
                dotted_name(call.args[0], mod) in _JIT_CALLS:
            return _donation_spec(call, mod)
        return None

    for node in ast.walk(mod.tree):
        # decorated defs: @jax.jit(...) / @functools.partial(jax.jit, ...)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    spec = jit_spec(dec)
                    if spec is not None:
                        donors[f"{mod.module}.{node.name}"] = \
                            _positions_for(node, spec)
        # assignment forms: g = jax.jit(f, donate_...=...)
        #                   g = functools.partial(jax.jit, donate_...)(f)
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, ast.Call):
            call = node.value
            fn_expr: Optional[ast.AST] = None
            spec = None
            head = dotted_name(call.func, mod)
            if head in _JIT_CALLS and call.args:
                spec = _donation_spec(call, mod)
                fn_expr = call.args[0]
            elif isinstance(call.func, ast.Call):
                spec = jit_spec(call.func)
                if spec is not None and call.args:
                    fn_expr = call.args[0]
            if spec is None or fn_expr is None:
                continue
            fn_dotted = dotted_name(fn_expr, mod)
            hit = index.resolve_function(fn_dotted) if fn_dotted else None
            if hit is not None:
                donors[f"{mod.module}.{node.targets[0].id}"] = \
                    _positions_for(hit[1], spec)
    return donors


# -- call-site checking ------------------------------------------------------

def _check_calls(mod: ModuleInfo, donors: Dict[str, _Donor]) \
        -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = dotted_name(node.func, mod)
        donor = donors.get(dotted or "")
        if donor is None:
            continue
        fn = mod.enclosing_function(node)
        if fn is None or isinstance(fn, ast.Lambda):
            continue
        # a donating call inside `return`/`raise` exits the function — no
        # later read in this body can ever follow it
        if any(isinstance(a, (ast.Return, ast.Raise))
               for a in _ancestors(fn, node)):
            continue
        donated = _donated_names(node, donor)
        for arg_name in sorted(donated):
            read = _read_after(fn, arg_name, node)
            if read is not None:
                short = (dotted or "").rsplit(".", 1)[-1]
                out.append(Finding(
                    code="DN001", path=mod.path, line=read.lineno,
                    col=read.col_offset,
                    message=f"`{arg_name}` was donated to `{short}` on "
                            f"line {node.lineno} (donate_argnums/argnames) "
                            f"and is read again here — the buffer may "
                            f"already be deleted; re-device_put or drop "
                            f"the donation"))
    return out


def _donated_names(call: ast.Call, donor: _Donor) -> Set[str]:
    names: Set[str] = set()
    for i, a in enumerate(call.args):
        if i in donor.positions and isinstance(a, ast.Name):
            names.add(a.id)
    for kw in call.keywords:
        if kw.arg in donor.names and isinstance(kw.value, ast.Name):
            names.add(kw.value.id)
    return names


def _read_after(fn: ast.AST, name: str, call: ast.Call) \
        -> Optional[ast.Name]:
    """First ``Load`` of ``name`` after the donating call and before the name
    is rebound (a rebind refreshes the buffer, ending the hazard window).

    Two kinds of read can never observe the donation and are skipped: args
    of the call expression itself when it spans multiple lines, and reads in
    an exclusive sibling branch of an ``if`` the call sits in — only one of
    the two branches runs, so a read in the other never follows the call.
    """
    call_line = getattr(call, "end_lineno", None) or call.lineno
    rebind_line = None
    for n in ast.walk(fn):
        if isinstance(n, ast.Name) and n.id == name and \
                isinstance(n.ctx, (ast.Store, ast.Del)) and \
                n.lineno > call_line:
            if rebind_line is None or n.lineno < rebind_line:
                rebind_line = n.lineno
    best: Optional[ast.Name] = None
    for n in ast.walk(fn):
        if isinstance(n, ast.Name) and n.id == name and \
                isinstance(n.ctx, ast.Load) and n.lineno > call_line:
            if rebind_line is not None and n.lineno >= rebind_line:
                continue
            if _exclusive_branches(fn, call, n):
                continue
            if best is None or (n.lineno, n.col_offset) < \
                    (best.lineno, best.col_offset):
                best = n
    return best


def _ancestors(fn: ast.AST, target: ast.AST) -> List[ast.AST]:
    """Ancestor chain of ``target`` inside ``fn`` (innermost first)."""
    path: List[ast.AST] = []

    def dfs(node: ast.AST) -> bool:
        if node is target:
            return True
        for child in ast.iter_child_nodes(node):
            if dfs(child):
                path.append(node)
                return True
        return False

    dfs(fn)
    return path


def _branch_path(fn: ast.AST, target: ast.AST) \
        -> Optional[List[Tuple[int, str]]]:
    """``(id(if_node), "body"|"orelse")`` pairs on the path to ``target``."""

    def dfs(node: ast.AST, acc: List[Tuple[int, str]]):
        if node is target:
            return acc
        if isinstance(node, ast.If):
            r = dfs(node.test, acc)
            if r is not None:
                return r
            for branch in ("body", "orelse"):
                for child in getattr(node, branch):
                    r = dfs(child, acc + [(id(node), branch)])
                    if r is not None:
                        return r
            return None
        for child in ast.iter_child_nodes(node):
            r = dfs(child, acc)
            if r is not None:
                return r
        return None

    return dfs(fn, [])


def _exclusive_branches(fn: ast.AST, a: ast.AST, b: ast.AST) -> bool:
    """True when ``a`` and ``b`` sit in opposite branches of a shared
    ``if`` — at most one of them executes on any given call."""
    pa, pb = _branch_path(fn, a), _branch_path(fn, b)
    if pa is None or pb is None:
        return False
    sides = dict(pa)
    return any(sides.get(key, side) != side for key, side in pb)
