"""``--fix`` — mechanical application of UN001 unit-suffix renames.

The fix engine applies exactly the rename the UN001 finding message
suggests, but *everywhere at once* via the project index:

* the field definition (``AnnAssign`` target) on the unit struct,
* ``self.<field>`` reads inside the struct's own body,
* keyword arguments at every indexed constructor call site
  (``EnergyReport(energy=...)`` → ``EnergyReport(energy_j=...)``),
* attribute reads through locally-inferred instances
  (``r = EnergyReport(...); r.energy`` in the same function),
* dict-literal string keys flagged inside struct methods.

The suffix is picked from the name (``energy`` → ``_j``, ``power`` →
``_w``, ``temp`` → ``_c``, ``freq`` → ``_ghz``, else ``_us`` — the
default the finding message itself suggests).  Renames that would collide
with an existing name, and findings silenced by a waiver, are skipped with
a note.  Edits are token-precise (line/col spans from the AST) and applied
bottom-up so earlier spans stay valid; a second run finds no UN001
violations, so ``--fix`` is idempotent by construction.  The engine only
renames — it never reorders, reformats, or otherwise rewrites code, so
runtime behavior is unchanged (every reference moves with its definition).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .config import AnalysisConfig
from .findings import scan_waivers
from .project import ModuleInfo, ProjectIndex, dotted_name
from .rules_units import UnitViolation, unit_violations

#: name-substring -> unit suffix; first hit wins, fallback ``_us`` (the
#: suggestion UN001's own message makes)
SUFFIX_HINTS: Tuple[Tuple[str, str], ...] = (
    ("energy", "_j"),
    ("power", "_w"),
    ("temp", "_c"),
    ("freq", "_ghz"),
    ("volt", "_v"),
)
DEFAULT_SUFFIX = "_us"


def suggest_name(name: str) -> str:
    low = name.lower()
    for hint, sfx in SUFFIX_HINTS:
        if hint in low:
            return name + sfx
    return name + DEFAULT_SUFFIX


@dataclasses.dataclass(frozen=True)
class Edit:
    """Replace ``length`` chars at ``(line, col)`` of ``path`` with
    ``replacement``."""
    path: str
    line: int                   # 1-based
    col: int                    # 0-based
    length: int
    replacement: str


@dataclasses.dataclass
class FixResult:
    edits: List[Edit]
    skipped: List[str]          # human-readable skip notes
    files: Set[str]             # files rewritten

    @property
    def applied(self) -> int:
        return len(self.edits)


def plan_fixes(index: ProjectIndex, cfg: AnalysisConfig) -> FixResult:
    """Compute the rename edit set for every unwaived UN001 violation."""
    edits: List[Edit] = []
    skipped: List[str] = []
    waivers = {mod.path: scan_waivers(mod.source, mod.tree)
               for mod in index.modules.values()}

    for v in unit_violations(index, cfg):
        w = waivers.get(v.mod.path, {}).get(v.node.lineno)
        if w is not None and "UN001" in w.codes:
            skipped.append(f"{v.mod.path}:{v.node.lineno}: `{v.name}` "
                           f"is waived — left as-is")
            continue
        new = suggest_name(v.name)
        if v.kind == "field":
            if _collides(v.cls, new):
                skipped.append(f"{v.mod.path}:{v.node.lineno}: renaming "
                               f"`{v.name}` -> `{new}` collides with an "
                               f"existing member — fix manually")
                continue
            edits.extend(_field_edits(index, v, new))
        else:
            edits.extend(_dict_key_edits(v, new))

    # drop duplicate spans (two violations can reference one site)
    seen: Set[Tuple[str, int, int]] = set()
    unique: List[Edit] = []
    for e in edits:
        key = (e.path, e.line, e.col)
        if key not in seen:
            seen.add(key)
            unique.append(e)
    return FixResult(edits=unique, skipped=skipped, files=set())


def apply_fixes(root: Path, result: FixResult) -> FixResult:
    """Write the planned edits to disk, bottom-up per file."""
    by_path: Dict[str, List[Edit]] = {}
    for e in result.edits:
        by_path.setdefault(e.path, []).append(e)
    for path, file_edits in by_path.items():
        fp = Path(root) / path
        lines = fp.read_text().splitlines(keepends=True)
        for e in sorted(file_edits, key=lambda e: (e.line, e.col),
                        reverse=True):
            text = lines[e.line - 1]
            lines[e.line - 1] = (text[:e.col] + e.replacement +
                                 text[e.col + e.length:])
        fp.write_text("".join(lines))
        result.files.add(path)
    return result


# -- edit derivation ---------------------------------------------------------

def _collides(cls: ast.ClassDef, new: str) -> bool:
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name) and stmt.target.id == new:
            return True
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and t.id == new:
                    return True
    return False


def _field_edits(index: ProjectIndex, v: UnitViolation,
                 new: str) -> List[Edit]:
    old = v.name
    edits: List[Edit] = []
    assert isinstance(v.node, ast.AnnAssign)
    target = v.node.target
    edits.append(Edit(path=v.mod.path, line=target.lineno,
                      col=target.col_offset, length=len(old),
                      replacement=new))

    # self.<old> anywhere in the struct body (methods, defaults)
    for node in ast.walk(v.cls):
        if isinstance(node, ast.Attribute) and node.attr == old and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            edits.append(_attr_edit(v.mod, node, old, new))

    cls_dotted = f"{v.mod.module}.{v.cls.name}" if v.mod.module \
        else v.cls.name
    for mod in index.modules.values():
        edits.extend(_call_site_edits(mod, cls_dotted, old, new))
    return [e for e in edits if e is not None]


def _attr_edit(mod: ModuleInfo, node: ast.Attribute, old: str,
               new: str) -> Optional[Edit]:
    """Edit for the ``.attr`` part of an Attribute node (after the dot)."""
    line = node.value.end_lineno
    src_line = mod.source.splitlines()[line - 1] if line is not None else ""
    start = node.value.end_col_offset
    idx = src_line.find(old, start if start is not None else 0)
    if idx < 0:                     # attr on a continuation line: find it
        for ln in range(node.value.end_lineno, node.end_lineno + 1):
            text = mod.source.splitlines()[ln - 1]
            idx = text.find(old)
            if idx >= 0 and text[:idx].rstrip().endswith("."):
                return Edit(path=mod.path, line=ln, col=idx,
                            length=len(old), replacement=new)
        return None
    return Edit(path=mod.path, line=line, col=idx, length=len(old),
                replacement=new)


def _call_site_edits(mod: ModuleInfo, cls_dotted: str, old: str,
                     new: str) -> List[Edit]:
    edits: List[Edit] = []
    # constructor keyword args
    ctor_vars: Dict[Tuple[ast.AST, str], bool] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and \
                dotted_name(node.func, mod) == cls_dotted:
            for kw in node.keywords:
                if kw.arg == old:
                    edits.append(Edit(path=mod.path, line=kw.lineno,
                                      col=kw.col_offset, length=len(old),
                                      replacement=new))
        # record vars assigned from the constructor for attribute renames
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, ast.Call) and \
                dotted_name(node.value.func, mod) == cls_dotted:
            fn = mod.enclosing_function(node) or mod.tree
            ctor_vars[(fn, node.targets[0].id)] = True
    # <var>.<old> where <var> is locally inferred as an instance
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Attribute) and node.attr == old and \
                isinstance(node.value, ast.Name):
            fn = mod.enclosing_function(node) or mod.tree
            if ctor_vars.get((fn, node.value.id)):
                e = _attr_edit(mod, node, old, new)
                if e is not None:
                    edits.append(e)
    return edits


def _dict_key_edits(v: UnitViolation, new: str) -> List[Edit]:
    node = v.node
    if isinstance(node, ast.Constant):       # {"energy": ...}
        raw = v.mod.source.splitlines()[node.lineno - 1]
        quote = raw[node.col_offset] if node.col_offset < len(raw) else '"'
        if quote not in "\"'":
            quote = '"'
        literal_len = (node.end_col_offset - node.col_offset
                       if node.end_lineno == node.lineno else len(v.name) + 2)
        return [Edit(path=v.mod.path, line=node.lineno,
                     col=node.col_offset, length=literal_len,
                     replacement=f"{quote}{new}{quote}")]
    if isinstance(node, ast.keyword):        # dict(energy=...)
        return [Edit(path=v.mod.path, line=node.lineno,
                     col=node.col_offset, length=len(v.name),
                     replacement=new)]
    return []
