"""CC001 — the jit compile-count regression gate.

Recompilation is the silent perf killer in this codebase: a pytree whose
static field became an array, an ``EpochSpec`` losing its hash, a sweep
rebuilding its grid per call — all show up first as ``*.compile_count``
creep, long before wall time makes it obvious.  The kernels already count
every trace (``repro.obs.metrics``) and every ``BENCH_*.json`` embeds the
counter snapshot in its run manifest, so the gate is pure bookkeeping:

* ``contracts.json`` (checked in) records, per benchmark, the maximum
  allowed value of each compile counter.
* :func:`check_compile_gate` loads one or more ``BENCH_*.json`` artifacts
  and emits a CC001 finding for every counter above its contract — and for
  any benchmark that has *no* contract entry, so new benchmarks must
  register a budget rather than silently escaping the gate.

Raising a contract is a reviewed diff of ``contracts.json``, with the
justification in the commit — exactly like a changed golden file.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Sequence

from .findings import Finding

CONTRACTS_SCHEMA = "repro.analysis/contracts/v1"


def load_contracts(path: Path) -> Dict[str, Dict[str, int]]:
    data = json.loads(Path(path).read_text())
    if data.get("schema") != CONTRACTS_SCHEMA:
        raise ValueError(f"{path}: expected schema {CONTRACTS_SCHEMA!r}, "
                         f"got {data.get('schema')!r}")
    return data["contracts"]


def _bench_counters(payload: Dict) -> Dict[str, float]:
    manifest = payload.get("manifest", {})
    counters = manifest.get("metrics", {}).get("counters", {})
    return {n: v for n, v in counters.items()
            if n.endswith("compile_count")}


def check_compile_gate(contracts_path: Path,
                       bench_paths: Sequence[Path]) -> List[Finding]:
    contracts = load_contracts(contracts_path)
    out: List[Finding] = []
    cpath = Path(contracts_path).as_posix()
    for bp in bench_paths:
        bp = Path(bp)
        try:
            payload = json.loads(bp.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            out.append(Finding(code="CC001", path=bp.as_posix(), line=1,
                               message=f"unreadable bench artifact: {exc}"))
            continue
        bench = payload.get("manifest", {}).get("bench")
        if not bench:
            out.append(Finding(code="CC001", path=bp.as_posix(), line=1,
                               message="bench artifact has no "
                                       "manifest.bench name"))
            continue
        contract = contracts.get(bench)
        if contract is None:
            out.append(Finding(
                code="CC001", path=cpath, line=1,
                message=f"benchmark `{bench}` has no compile-count "
                        f"contract; add an entry before it lands in CI"))
            continue
        counters = _bench_counters(payload)
        for name, limit in sorted(contract.items()):
            actual = counters.get(name, 0)
            if actual > limit:
                out.append(Finding(
                    code="CC001", path=cpath, line=1,
                    message=f"benchmark `{bench}`: counter `{name}` hit "
                            f"{actual:g} compiles, contract allows "
                            f"{limit} (+{actual - limit:g} over budget) — "
                            f"a jit cache key regressed (or raise the "
                            f"contract with justification)"))
        stray = sorted(set(counters) - set(contract))
        for name in stray:
            if counters[name] > 0:
                out.append(Finding(
                    code="CC001", path=cpath, line=1,
                    message=f"`{bench}`: counter `{name}` "
                            f"({counters[name]:g} compiles) is not in the "
                            f"contract; budget it explicitly"))
    return out
