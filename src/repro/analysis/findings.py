"""Findings + inline waivers for the ``repro.analysis`` lint suite.

A :class:`Finding` is one rule violation pinned to a file/line.  Waivers are
inline comments that acknowledge a finding instead of fixing it::

    compile_count.inc()   # lint: waive JX003 -- compile counter, trace-only

    # lint: waive UN001 -- ratio, dimensionless by construction
    offending_line = ...

The first form waives codes on its own line; a standalone waiver comment
waives the *next* line.  The justification after ``--`` is required in
``--strict`` runs: a bare waiver raises ``WV001`` so silent suppressions
cannot accumulate (DESIGN.md §12).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence

REPORT_SCHEMA = "repro.analysis/report/v1"

#: ``# lint: waive JX001[,JX002] [-- justification]``
WAIVER_RE = re.compile(
    r"#\s*lint:\s*waive\s+"
    r"(?P<codes>[A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
    r"\s*(?:--\s*(?P<reason>\S.*?))?\s*$")

#: severity -> the word rendered in reports (and matched by the CI problem
#: matcher / mapped to SARIF result levels)
SEVERITY_WORD = {"error": "error", "warn": "warning", "info": "note"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or acknowledged waiver) at ``path:line``."""
    code: str                       # e.g. "JX001"
    path: str                       # repo-relative file path
    line: int                       # 1-based
    message: str
    col: int = 0
    severity: str = "error"         # error | warn | info
    waived: bool = False
    waiver_reason: Optional[str] = None

    def render(self) -> str:
        tag = " (waived)" if self.waived else ""
        word = SEVERITY_WORD.get(self.severity, self.severity)
        return f"{self.path}:{self.line}:{self.col}: {self.code} " \
               f"{word}{tag}: {self.message}"

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Waiver:
    codes: frozenset
    reason: Optional[str]
    line: int                       # the waiver comment's own line


def scan_waivers(source: str,
                 tree: Optional[ast.Module] = None) -> Dict[int, Waiver]:
    """Map *waived line number* -> :class:`Waiver` for one file.

    A waiver comment trailing code applies to its own line; a comment-only
    waiver line applies to itself and the following line (so long statements
    can carry the waiver above them).  When the parsed ``tree`` is supplied
    two further forms resolve:

    * a trailing waiver on a **continuation line** of a multi-line statement
      also covers the statement's reporting line (its ``lineno``), so a
      finding pinned to the statement start is still waivable in place;
    * a standalone waiver above a **decorated def/class** also covers the
      ``def``/``class`` line itself (the comment's "next line" is the first
      decorator, but findings pin to the definition line).
    """
    out: Dict[int, Waiver] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = WAIVER_RE.search(text)
        if not m:
            continue
        codes = frozenset(c.strip() for c in m.group("codes").split(","))
        w = Waiver(codes=codes, reason=m.group("reason"), line=i)
        out[i] = w
        if text.lstrip().startswith("#"):      # standalone comment line
            out.setdefault(i + 1, w)
            if tree is not None:
                target = _decorated_def_line(tree, i + 1)
                if target is not None:
                    out.setdefault(target, w)
        elif tree is not None:
            start = _statement_start(tree, i)
            if start is not None and start != i:
                out.setdefault(start, w)
    return out


def _decorated_def_line(tree: ast.Module, line: int) -> Optional[int]:
    """The ``def``/``class`` line when ``line`` is its first decorator."""
    for node in ast.walk(tree):
        decs = getattr(node, "decorator_list", None)
        if decs and decs[0].lineno == line:
            return node.lineno
    return None


def _statement_start(tree: ast.Module, line: int) -> Optional[int]:
    """Reporting line of the innermost statement spanning ``line``."""
    best: Optional[int] = None
    for node in ast.walk(tree):
        if not isinstance(node, ast.stmt):
            continue
        end = getattr(node, "end_lineno", None)
        if end is None or not (node.lineno <= line <= end):
            continue
        if best is None or node.lineno > best:
            best = node.lineno
    return best


def apply_waivers(findings: Sequence[Finding],
                  waivers_by_path: Dict[str, Dict[int, Waiver]],
                  strict: bool = False) -> List[Finding]:
    """Mark findings covered by a waiver; in strict mode add ``WV001`` for
    waivers that carry no ``--`` justification."""
    out: List[Finding] = []
    used: set = set()
    for f in findings:
        w = waivers_by_path.get(f.path, {}).get(f.line)
        if w is not None and f.code in w.codes:
            used.add((f.path, w.line))
            out.append(dataclasses.replace(f, waived=True,
                                           waiver_reason=w.reason))
        else:
            out.append(f)
    if strict:
        for path, waivers in waivers_by_path.items():
            for w in set(waivers.values()):
                if w.reason is None:
                    out.append(Finding(
                        code="WV001", path=path, line=w.line,
                        message="waiver without justification; append "
                                "'-- <why this is safe>'"))
    return out


def active(findings: Sequence[Finding]) -> List[Finding]:
    """Findings not silenced by a waiver (any severity)."""
    return [f for f in findings if not f.waived]


def gating(findings: Sequence[Finding], strict: bool = False) \
        -> List[Finding]:
    """Active findings that fail the run: ``error`` always, ``warn`` only
    under ``--strict`` (the CI mode), ``info`` never."""
    levels = ("error", "warn") if strict else ("error",)
    return [f for f in active(findings) if f.severity in levels]


def render_report(findings: Sequence[Finding]) -> str:
    lines = [f.render() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.code))]
    act = active(findings)
    waived = len(findings) - len(act)
    per_sev = {lvl: sum(1 for f in act if f.severity == lvl)
               for lvl in ("error", "warn", "info")}
    lines.append(f"{len(act)} finding(s) "
                 f"({per_sev['error']} error, {per_sev['warn']} warn, "
                 f"{per_sev['info']} info), {waived} waived")
    return "\n".join(lines)


def report_payload(findings: Sequence[Finding], **extra) -> Dict:
    """JSON-ready findings report (the CI artifact next to BENCH_*.json)."""
    per_code: Dict[str, int] = {}
    for f in active(findings):
        per_code[f.code] = per_code.get(f.code, 0) + 1
    per_sev: Dict[str, int] = {}
    for f in active(findings):
        per_sev[f.severity] = per_sev.get(f.severity, 0) + 1
    payload = {
        "schema": REPORT_SCHEMA,
        "findings": [f.to_dict() for f in sorted(
            findings, key=lambda f: (f.path, f.line, f.code))],
        "summary": {"active": len(active(findings)),
                    "waived": len(findings) - len(active(findings)),
                    "per_code": dict(sorted(per_code.items())),
                    "per_severity": dict(sorted(per_sev.items()))},
    }
    payload.update(extra)
    return payload


def dump_report(findings: Sequence[Finding], path: str, **extra) -> None:
    with open(path, "w") as fh:
        json.dump(report_payload(findings, **extra), fh, indent=2)
