"""Resource database — processing elements and profiled task latencies.

Faithful to the paper (CODES/ISSS'19, Tables 1 & 2): the resource database
holds the list of PEs along with the *expected latency of tasks* profiled on
reference hardware (Odroid-XU3 A7/A15 clusters, Zynq ZCU-102 accelerators).

Latencies are in microseconds.  ``inf`` (absent entry) means the PE cannot
execute that task.  CPU PEs scale latency with a DVFS frequency multiplier;
hardware accelerators run at a fixed clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

INF = math.inf

# --------------------------------------------------------------------------
# PE types
# --------------------------------------------------------------------------

CPU_BIG = "A15"        # ARM big  (Odroid-XU3 Cortex-A15)
CPU_LITTLE = "A7"      # ARM LITTLE (Odroid-XU3 Cortex-A7)
ACC_SCRAMBLER = "SCR_ACC"   # Scrambler-Encoder hardware accelerator
ACC_FFT = "FFT_ACC"         # FFT hardware accelerator
ACC_VITERBI = "VIT_ACC"     # Viterbi decoder accelerator (WiFi-RX)

CPU_TYPES = (CPU_BIG, CPU_LITTLE)

# Nominal DVFS operating points (GHz, Volt) per CPU cluster — Odroid-XU3.
OPP_TABLE: Dict[str, List[Tuple[float, float]]] = {
    CPU_BIG: [(0.6, 0.90), (1.0, 1.00), (1.4, 1.1), (1.8, 1.2), (2.0, 1.25)],
    CPU_LITTLE: [(0.6, 0.95), (0.8, 1.00), (1.0, 1.05), (1.2, 1.15), (1.4, 1.25)],
}
NOMINAL_FREQ = {CPU_BIG: 2.0, CPU_LITTLE: 1.4}

# Effective switching capacitance (nF) + leakage (W) — calibrated after
# Bhat et al., TVLSI'18 power models for the same board.
POWER_COEFF = {
    CPU_BIG: dict(ceff=0.45, leak=0.25),
    CPU_LITTLE: dict(ceff=0.10, leak=0.03),
    ACC_SCRAMBLER: dict(ceff=0.02, leak=0.01),
    ACC_FFT: dict(ceff=0.05, leak=0.02),
    ACC_VITERBI: dict(ceff=0.05, leak=0.02),
}
ACC_POWER_ACTIVE = {ACC_SCRAMBLER: 0.15, ACC_FFT: 0.35, ACC_VITERBI: 0.30}


@dataclasses.dataclass(frozen=True)
class PE:
    """A processing element instance in the SoC."""
    pe_id: int
    pe_type: str            # one of the type names above
    cluster: int            # DVFS / comm domain id
    name: str = ""

    @property
    def is_cpu(self) -> bool:
        return self.pe_type in CPU_TYPES


@dataclasses.dataclass
class CommModel:
    """Analytical on-chip interconnect latency model (paper §2).

    latency(bytes, src, dst) = 0 if same PE;
    startup + bytes/bw, doubled when crossing clusters (bus + memory hop).
    """
    startup_us: float = 0.5
    bw_bytes_per_us: float = 8_000.0     # ~8 GB/s effective on-chip
    cross_cluster_penalty: float = 2.0

    def latency(self, nbytes: float, src: Optional[PE], dst: PE) -> float:
        if src is None or src.pe_id == dst.pe_id:
            return 0.0
        t = self.startup_us + nbytes / self.bw_bytes_per_us
        if src.cluster != dst.cluster:
            t *= self.cross_cluster_penalty
        return t


class ResourceDB:
    """The resource database: PEs + profiled per-task latencies.

    ``profiles`` maps task name -> {pe_type: latency_us}.
    """

    def __init__(self, pes: Sequence[PE], profiles: Mapping[str, Mapping[str, float]],
                 comm: Optional[CommModel] = None):
        self.pes: List[PE] = list(pes)
        self.profiles: Dict[str, Dict[str, float]] = {k: dict(v) for k, v in profiles.items()}
        self.comm = comm or CommModel()

    # -- queries ----------------------------------------------------------
    @property
    def num_pes(self) -> int:
        return len(self.pes)

    def latency(self, task_name: str, pe: PE, freq_scale: float = 1.0) -> float:
        """Expected latency (us) of ``task_name`` on ``pe``.

        ``freq_scale`` = f_nominal / f_current for CPU PEs (DVFS slowdown).
        """
        base = self.profiles.get(task_name, {}).get(pe.pe_type, INF)
        if not pe.is_cpu:
            return base
        return base * freq_scale

    def supports(self, task_name: str, pe: PE) -> bool:
        return self.profiles.get(task_name, {}).get(pe.pe_type, INF) != INF

    def latency_matrix(self, task_names: Sequence[str]):
        """Dense (num_tasks × num_pes) latency table (INF = unsupported)."""
        import numpy as np
        mat = np.full((len(task_names), self.num_pes), np.inf, dtype=np.float32)
        for i, t in enumerate(task_names):
            for j, pe in enumerate(self.pes):
                mat[i, j] = self.profiles.get(t, {}).get(pe.pe_type, INF)
        return mat

    def pes_of_type(self, pe_type: str) -> List[PE]:
        return [p for p in self.pes if p.pe_type == pe_type]


# --------------------------------------------------------------------------
# Paper Table 1 — WiFi-TX execution profiles (us) on Odroid A7/A15 + accs.
# --------------------------------------------------------------------------
WIFI_TX_PROFILES: Dict[str, Dict[str, float]] = {
    "scrambler_encoder": {ACC_SCRAMBLER: 8, CPU_LITTLE: 22, CPU_BIG: 10},
    "interleaver":       {CPU_LITTLE: 10, CPU_BIG: 4},
    "qpsk_modulation":   {CPU_LITTLE: 15, CPU_BIG: 8},
    "pilot_insertion":   {CPU_LITTLE: 5,  CPU_BIG: 3},
    "inverse_fft":       {ACC_FFT: 16, CPU_LITTLE: 296, CPU_BIG: 118},
    "crc":               {CPU_LITTLE: 5,  CPU_BIG: 3},
}

# Representative profiles for the other four reference applications
# (WiFi-RX, single-carrier low-power, range detection, pulse Doppler), in the
# style of the released DS3 benchmark suite (Zynq/Odroid profiled).
EXTRA_PROFILES: Dict[str, Dict[str, float]] = {
    # WiFi-RX
    "match_filter":      {CPU_LITTLE: 28, CPU_BIG: 12},
    "payload_extract":   {CPU_LITTLE: 8,  CPU_BIG: 4},
    "fft":               {ACC_FFT: 16, CPU_LITTLE: 296, CPU_BIG: 118},
    "pilot_extract":     {CPU_LITTLE: 6,  CPU_BIG: 3},
    "qpsk_demodulation": {CPU_LITTLE: 18, CPU_BIG: 9},
    "deinterleaver":     {CPU_LITTLE: 12, CPU_BIG: 5},
    "viterbi_decoder":   {ACC_VITERBI: 20, CPU_LITTLE: 520, CPU_BIG: 190},
    # Single-carrier (low-power) TX/RX
    "sc_modulation":     {CPU_LITTLE: 10, CPU_BIG: 5},
    "sc_demodulation":   {CPU_LITTLE: 12, CPU_BIG: 6},
    "rrc_filter":        {CPU_LITTLE: 45, CPU_BIG: 18},
    "sync":              {CPU_LITTLE: 30, CPU_BIG: 12},
    # Range detection (LFM correlation)
    "lfm_gen":           {CPU_LITTLE: 14, CPU_BIG: 6},
    "conj_multiply":     {CPU_LITTLE: 24, CPU_BIG: 10},
    "amplitude":         {CPU_LITTLE: 12, CPU_BIG: 5},
    "peak_detect":       {CPU_LITTLE: 8,  CPU_BIG: 4},
    # Pulse Doppler
    "pd_stack":          {CPU_LITTLE: 10, CPU_BIG: 4},
    "doppler_fft":       {ACC_FFT: 16, CPU_LITTLE: 296, CPU_BIG: 118},
    "cfar":              {CPU_LITTLE: 40, CPU_BIG: 16},
}

ALL_PROFILES: Dict[str, Dict[str, float]] = {**WIFI_TX_PROFILES, **EXTRA_PROFILES}


def make_soc_table2(with_viterbi: bool = False) -> ResourceDB:
    """SoC configuration of paper Table 2.

    4× Cortex-A15 (big), 4× Cortex-A7 (LITTLE), 2× Scrambler-Encoder
    accelerators, 4× FFT accelerators  — 14 PEs total.
    """
    pes: List[PE] = []
    idx = 0
    for i in range(4):
        pes.append(PE(idx, CPU_BIG, cluster=0, name=f"A15-{i}")); idx += 1
    for i in range(4):
        pes.append(PE(idx, CPU_LITTLE, cluster=1, name=f"A7-{i}")); idx += 1
    for i in range(2):
        pes.append(PE(idx, ACC_SCRAMBLER, cluster=2, name=f"SCR-{i}")); idx += 1
    for i in range(4):
        pes.append(PE(idx, ACC_FFT, cluster=2, name=f"FFT-{i}")); idx += 1
    if with_viterbi:
        pes.append(PE(idx, ACC_VITERBI, cluster=2, name="VIT-0")); idx += 1
    return ResourceDB(pes, ALL_PROFILES)


def make_soc(num_big: int = 4, num_little: int = 4, num_scr: int = 2,
             num_fft: int = 4, num_vit: int = 0,
             profiles: Optional[Mapping[str, Mapping[str, float]]] = None,
             comm: Optional[CommModel] = None) -> ResourceDB:
    """Arbitrary SoC configuration for design-space exploration.

    ``comm`` overrides the interconnect model (e.g. a different cross-cluster
    penalty per design point); cluster-frequency caps are applied when the
    simulation tables are built (``build_tables`` + a userspace governor).
    """
    pes: List[PE] = []
    idx = 0
    for i in range(num_big):
        pes.append(PE(idx, CPU_BIG, 0, f"A15-{i}")); idx += 1
    for i in range(num_little):
        pes.append(PE(idx, CPU_LITTLE, 1, f"A7-{i}")); idx += 1
    for i in range(num_scr):
        pes.append(PE(idx, ACC_SCRAMBLER, 2, f"SCR-{i}")); idx += 1
    for i in range(num_fft):
        pes.append(PE(idx, ACC_FFT, 2, f"FFT-{i}")); idx += 1
    for i in range(num_vit):
        pes.append(PE(idx, ACC_VITERBI, 2, f"VIT-{i}")); idx += 1
    return ResourceDB(pes, dict(profiles) if profiles else ALL_PROFILES,
                      comm=comm)
