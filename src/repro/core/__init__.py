"""DS3X core — the paper's DSSoC simulation framework, faithful half.

Public API:
    resources:    PE, ResourceDB, CommModel, make_soc_table2, make_soc
    applications: Application, Task, get_application, REFERENCE_APPS
    jobgen:       JobTrace, poisson_trace, deterministic_trace, rate_sweep
    schedulers:   get_scheduler, register_scheduler, solve_optimal_table
    simkernel:    simulate (reference) / build_tables + simulate_jax (vectorised)
    power/thermal/dvfs: analytical models + governors
"""
from .applications import (Application, REFERENCE_APPS, Task, get_application,
                           pulse_doppler, range_detection, single_carrier,
                           wifi_rx, wifi_tx)
from .dvfs import (GOVERNORS, Governor, OndemandGovernor, PerformanceGovernor,
                   PowersaveGovernor, UserspaceGovernor, get_governor)
from .jobgen import JobTrace, deterministic_trace, poisson_trace, rate_sweep
from .power import EnergyReport, active_power, energy_from_schedule, idle_power
from .resources import (ACC_FFT, ACC_SCRAMBLER, ACC_VITERBI, CPU_BIG,
                        CPU_LITTLE, CommModel, PE, ResourceDB, make_soc,
                        make_soc_table2)
from .schedulers import (ETFScheduler, METScheduler, SchedContext, Scheduler,
                         TableScheduler, available_schedulers, get_scheduler,
                         register_scheduler, solve_optimal_table)
from .simkernel_jax import SimTables, build_tables, simulate_batch, simulate_jax
from .simkernel_ref import SimResult, TaskRecord, simulate
from . import reports, thermal

__all__ = [n for n in dir() if not n.startswith("_")]
