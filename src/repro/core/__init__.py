"""DS3X core — the paper's DSSoC simulation framework, faithful half.

Public API:
    resources:    PE, ResourceDB, CommModel, make_soc_table2, make_soc
    applications: Application, Task, get_application, REFERENCE_APPS
    jobgen:       JobTrace, poisson_trace, deterministic_trace, rate_sweep
    schedulers:   get_scheduler, register_scheduler, solve_optimal_table
    simkernel:    simulate (reference) / build_tables + simulate_jax (vectorised)
    power/thermal/dvfs: analytical models + governors

Scenario-driven entry point: prefer ``repro.scenario`` — one declarative
``Scenario`` plus ``run()``/``sweep()`` replaces wiring the pieces above by
hand.  ``simulate`` / ``simulate_jax`` exported here are deprecation shims
that delegate to the unchanged kernels.
"""
from ._deprecation import deprecated_entry_point as _deprecated_entry_point
from .applications import (Application, REFERENCE_APPS, Task, get_application,
                           pulse_doppler, range_detection, single_carrier,
                           wifi_rx, wifi_tx)
from .dvfs import (GOVERNORS, Governor, GovernorPolicy, OndemandGovernor,
                   PerformanceGovernor, PowersaveGovernor, ThrottleGovernor,
                   UserspaceGovernor, get_governor, ondemand_index,
                   stack_policies, throttle_index)
from .jobgen import JobTrace, deterministic_trace, poisson_trace, rate_sweep
from .power import EnergyReport, active_power, energy_from_schedule, idle_power
from .resources import (ACC_FFT, ACC_SCRAMBLER, ACC_VITERBI, CPU_BIG,
                        CPU_LITTLE, CommModel, PE, ResourceDB, make_soc,
                        make_soc_table2)
from .schedulers import (ETFScheduler, METScheduler, SchedContext, Scheduler,
                         TableScheduler, available_schedulers, get_scheduler,
                         register_scheduler, solve_optimal_table)
from .simkernel_jax import SimTables, build_tables
from .simkernel_jax import simulate_batch as _simulate_batch_impl
from .simkernel_jax import simulate_jax as _simulate_jax_impl
from .simkernel_ref import SimResult, TaskRecord
from .simkernel_ref import simulate as _simulate_impl
from . import reports, thermal


simulate = _deprecated_entry_point(
    _simulate_impl,
    "repro.scenario.run(Scenario(...), backend='ref')")
simulate_jax = _deprecated_entry_point(
    _simulate_jax_impl,
    "repro.scenario.run(Scenario(...), backend='jax')")
simulate_batch = _deprecated_entry_point(
    _simulate_batch_impl,
    "repro.scenario.sweep(Scenario(...), axes={'trace': ...})")


__all__ = [n for n in dir() if not n.startswith("_")]
