"""Vectorised JAX simulation kernel — batched design-space exploration.

The paper's speed story (system-level simulation ~600× faster than cycle
accurate gem5) is re-thought for accelerators: instead of making *one*
event-heap simulation fast, the whole simulator becomes a fixed-shape tensor
program (an epoch-based ``lax.scan`` + masked argmin selects) so that
**thousands of simulations — seeds × injection rates × SoC configs ×
schedulers × DTPM policies — run batched under ``vmap``/``jit``**.

Semantics are identical to ``simkernel_ref`` (same epoch ordering, same
tie-breaking, float32 arithmetic): the two kernels are cross-validated in
``tests/test_sim_equivalence.py`` and ``tests/test_dtpm.py``.

Supported here: MET / ETF / table schedulers with *static* DVFS governors
(performance / powersave / userspace — one OPP baked into the tables) **and
dynamic DTPM policies** (the ondemand family): the epoch scan closes the
DVFS loop inside the compiled program — each sampling window gathers
per-cluster utilisation, applies the shared :func:`~repro.core.dvfs.
ondemand_index` transition, advances the §6 RC thermal network by its exact
update and clamps clusters above the thermal cap — and execution latency is
re-indexed from a precomputed (A, T, P, K) OPP table, so ``exec_us`` stays a
gather, never a re-profile (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .applications import Application
from .dvfs import (Governor, GovernorPolicy, MAX_OPP_LEVELS,
                   PerformanceGovernor, ondemand_index, padded_ladder,
                   throttle_index, validate_policy_params)
from .power import active_power, idle_power
from .resources import NOMINAL_FREQ, ResourceDB
from . import thermal as _thermal
from ..obs.metrics import counter as _obs_counter

BIG = jnp.float32(1e30)

# jit-trace counters (the python bodies below run only on compile): the run
# manifest reports them, tests assert the telemetry path never re-traces the
# simulation programs (DESIGN.md §11)
_COMPILES_STATIC = _obs_counter("kernel.jax.simulate.compile_count")
_COMPILES_DTPM = _obs_counter("kernel.jax.simulate_dtpm.compile_count")
_COMPILES_TELEMETRY = _obs_counter("obs.telemetry.scan.compile_count")

# Frequency domains: one per SoC cluster; make_soc uses 0=big, 1=LITTLE,
# 2=accelerator fabric.  Padded PE slots map to the last (accel) domain,
# which never moves (one OPP level) and carries zero power — inert.
MIN_DOMAINS = 3


# --------------------------------------------------------------------------
# Static tables (device-resident constants per (db, apps, governor) triple)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimTables:
    exec_us: jnp.ndarray        # (A, T, P) f32 — DVFS-scaled latency, BIG=unsupported
    pred: jnp.ndarray           # (A, T, T) bool
    ebytes: jnp.ndarray         # (A, T, T) f32 (bytes flowing t' -> t)
    valid: jnp.ndarray          # (A, T) bool
    comm_mult: jnp.ndarray      # (P, P) f32 in {0,1,penalty}
    comm_startup: jnp.ndarray   # () f32
    comm_inv_bw: jnp.ndarray    # () f32
    power_active: jnp.ndarray   # (P,) f32  W while busy
    power_idle: jnp.ndarray     # (P,) f32  W while idle
    table_pe: jnp.ndarray       # (A, T) i32 — table-scheduler assignment (or -1)
    node_of_pe: jnp.ndarray     # (P,) i32 thermal node per PE slot
    pe_domain: jnp.ndarray      # (P,) i32 frequency domain (cluster) per slot
    pe_is_cpu: jnp.ndarray      # (P,) f32 1.0 = CPU slot (counts in util)
    # DTPM-only OPP tables (None for static-governor tables):
    exec_opp: Optional[jnp.ndarray] = None          # (A, T, P, K) f32
    power_active_opp: Optional[jnp.ndarray] = None  # (P, K) f32
    opp_freq: Optional[jnp.ndarray] = None          # (C, K) f32 asc, top-padded
    num_opp: Optional[jnp.ndarray] = None           # (C,) i32 real level count
    domain_node: Optional[jnp.ndarray] = None       # (C,) i32 thermal node
    domain_cpu: Optional[jnp.ndarray] = None        # (C,) f32 CPU PEs per domain
    t_max: int = 0
    num_pes: int = 0


jax.tree_util.register_dataclass(
    SimTables,
    data_fields=["exec_us", "pred", "ebytes", "valid", "comm_mult",
                 "comm_startup", "comm_inv_bw", "power_active", "power_idle",
                 "table_pe", "node_of_pe", "pe_domain", "pe_is_cpu",
                 "exec_opp", "power_active_opp", "opp_freq", "num_opp",
                 "domain_node", "domain_cpu"],
    meta_fields=["t_max", "num_pes"],
)


def build_tables(db: ResourceDB, apps: Sequence[Application],
                 governor: Optional[Governor] = None,
                 table: Optional[Dict[Tuple[str, int], int]] = None,
                 pad_tasks: Optional[int] = None,
                 pad_pes: Optional[int] = None,
                 freq_caps: Optional[Mapping[str, float]] = None) -> SimTables:
    """Build device-resident simulation tables for one SoC design.

    ``pad_tasks`` / ``pad_pes`` pad the task and PE axes to a fixed size so
    tables from *different* designs stack into one (D, …) batch (see
    ``repro.dse.batch``).  Padding is inert by construction: padded task rows
    are invalid (pre-scheduled), padded PE columns carry BIG latency (never
    win an argmin) and zero active/idle power (no energy contribution).

    A *dynamic* governor (``governor.policy().dynamic``) additionally builds
    the OPP-indexed tables the DTPM kernel gathers from: per-level execution
    latency ``exec_opp``, per-level active power, and the per-domain OPP
    frequency ladders.  ``freq_caps`` (pe_type → max GHz) truncates each
    ladder — the design's hardware envelope; it defaults to the governor's
    own ``freq_caps`` (attached by ``Scenario.make_governor`` from the
    design point), keeping ref and jax on the same capped OPP set.
    """
    governor = governor or PerformanceGovernor()
    dynamic = governor.policy().dynamic
    if freq_caps is None:
        freq_caps = getattr(governor, "freq_caps", None)
    A = len(apps)
    T = max(a.num_tasks for a in apps)
    P = db.num_pes
    if pad_tasks is not None:
        if pad_tasks < T:
            raise ValueError(f"pad_tasks={pad_tasks} < max tasks {T}")
        T = pad_tasks
    if pad_pes is not None:
        if pad_pes < P:
            raise ValueError(f"pad_pes={pad_pes} < num_pes {P}")
        P = pad_pes

    freq = {}
    for pe in db.pes:
        if pe.is_cpu and pe.cluster not in freq:
            freq[pe.cluster] = governor.initial_freq(pe.pe_type)

    exec_us = np.full((A, T, P), 1e30, dtype=np.float32)
    pred = np.zeros((A, T, T), dtype=bool)
    ebytes = np.zeros((A, T, T), dtype=np.float32)
    valid = np.zeros((A, T), dtype=bool)
    table_pe = np.full((A, T), -1, dtype=np.int32)

    for ai, app in enumerate(apps):
        lat = db.latency_matrix(app.task_names)      # (t, P), inf unsupported
        for t in range(app.num_tasks):
            valid[ai, t] = True
            for j, pe in enumerate(db.pes):
                base = lat[t, j]
                if np.isfinite(base):
                    scale = (NOMINAL_FREQ[pe.pe_type] / freq[pe.cluster]
                             if pe.is_cpu else 1.0)
                    exec_us[ai, t, j] = np.float32(np.float32(base) * np.float32(scale))
            if table is not None:
                table_pe[ai, t] = table.get((app.name, t), -1)
        pred[ai, :app.num_tasks, :app.num_tasks] = app.pred_matrix()
        ebytes[ai, :app.num_tasks, :app.num_tasks] = app.edge_bytes_matrix()

    comm_mult = np.zeros((P, P), dtype=np.float32)
    for s in range(db.num_pes):
        for d in range(db.num_pes):
            if s == d:
                continue
            comm_mult[s, d] = (db.comm.cross_cluster_penalty
                               if db.pes[s].cluster != db.pes[d].cluster else 1.0)

    p_act = np.zeros(P, dtype=np.float32)
    p_idle = np.zeros(P, dtype=np.float32)
    for j, pe in enumerate(db.pes):
        f = freq.get(pe.cluster, 0.0) if pe.is_cpu else 0.0
        p_act[j] = active_power(pe, f)
        p_idle[j] = idle_power(pe)

    # frequency-domain / thermal-node maps (padded slots are inert: zero
    # power, non-CPU, binned to the accel node/domain by convention)
    C = max(MIN_DOMAINS, max(pe.cluster for pe in db.pes) + 1)
    node_of_pe = np.full(P, _thermal.NODE_ACCEL, dtype=np.int32)
    node_of_pe[:db.num_pes] = _thermal.cluster_nodes(db)
    pe_domain = np.full(P, C - 1, dtype=np.int32)
    pe_is_cpu = np.zeros(P, dtype=np.float32)
    for j, pe in enumerate(db.pes):
        pe_domain[j] = pe.cluster
        pe_is_cpu[j] = 1.0 if pe.is_cpu else 0.0

    opp_kw: Dict[str, jnp.ndarray] = {}
    if dynamic:
        opp_kw = _build_opp_tables(db, apps, A, T, P, C, freq_caps)

    return SimTables(
        exec_us=jnp.asarray(exec_us),
        pred=jnp.asarray(pred), ebytes=jnp.asarray(ebytes),
        valid=jnp.asarray(valid),
        comm_mult=jnp.asarray(comm_mult),
        comm_startup=jnp.float32(db.comm.startup_us),
        comm_inv_bw=jnp.float32(1.0 / db.comm.bw_bytes_per_us),
        power_active=jnp.asarray(p_act), power_idle=jnp.asarray(p_idle),
        table_pe=jnp.asarray(table_pe),
        node_of_pe=jnp.asarray(node_of_pe),
        pe_domain=jnp.asarray(pe_domain),
        pe_is_cpu=jnp.asarray(pe_is_cpu),
        t_max=T, num_pes=P, **opp_kw)


def _build_opp_tables(db: ResourceDB, apps: Sequence[Application],
                      A: int, T: int, P: int, C: int,
                      freq_caps: Optional[Mapping[str, float]]) -> Dict:
    """The (…, K) OPP-indexed tables the DTPM kernel gathers from.

    Level ladders are ascending and top-padded by repeating the highest real
    level; ``num_opp`` bounds the real counts (truncated under ``freq_caps``,
    but never below one level).  ``exec_opp`` quantises exactly like the
    reference path — f32(base) · f32(nominal/f) — so the two kernels see
    bit-identical latencies at every OPP.
    """
    K = MAX_OPP_LEVELS
    exec_opp = np.full((A, T, P, K), 1e30, dtype=np.float32)
    p_act_opp = np.zeros((P, K), dtype=np.float32)
    opp_freq = np.zeros((C, K), dtype=np.float32)
    num_opp = np.ones(C, dtype=np.int32)
    domain_node = np.full(C, _thermal.NODE_ACCEL, dtype=np.int32)
    domain_cpu = np.zeros(C, dtype=np.float32)
    nodes = _thermal.cluster_nodes(db)
    ladders = {pe.pe_type: padded_ladder(pe.pe_type, freq_caps)
               for pe in db.pes if pe.is_cpu}

    for j, pe in enumerate(db.pes):
        if pe.is_cpu:
            _, row, n = ladders[pe.pe_type]
            c = pe.cluster
            num_opp[c] = n
            domain_node[c] = nodes[j]
            domain_cpu[c] += 1.0
            for k in range(K):
                opp_freq[c, k] = row[k]
                p_act_opp[j, k] = active_power(pe, row[k])
        else:
            p_act_opp[j, :] = active_power(pe, 0.0)

    for ai, app in enumerate(apps):
        lat = db.latency_matrix(app.task_names)
        for t in range(app.num_tasks):
            for j, pe in enumerate(db.pes):
                base = lat[t, j]
                if not np.isfinite(base):
                    continue
                if pe.is_cpu:
                    _, row, _ = ladders[pe.pe_type]
                    for k in range(K):
                        scale = np.float32(NOMINAL_FREQ[pe.pe_type] / row[k])
                        exec_opp[ai, t, j, k] = np.float32(
                            np.float32(base) * scale)
                else:
                    exec_opp[ai, t, j, :] = np.float32(base)

    return dict(exec_opp=jnp.asarray(exec_opp),
                power_active_opp=jnp.asarray(p_act_opp),
                opp_freq=jnp.asarray(opp_freq),
                num_opp=jnp.asarray(num_opp),
                domain_node=jnp.asarray(domain_node),
                domain_cpu=jnp.asarray(domain_cpu))


# --------------------------------------------------------------------------
# The per-window DTPM transition — one function, two drivers
# --------------------------------------------------------------------------

def _window_step(tables: SimTables, valid_j, window, up, cap, A_rc, B_rc,
                 st, carry):
    """One sampling window: utilisation → governor step, window power →
    exact RC step, temperature → throttle clamp (ref kernel order).

    ``carry`` is ``(opp_idx, next_w, temps, peak)``.  Returns the advanced
    carry plus the window's observables ``(util, node_power_w)`` — the DTPM
    epoch scan drives this lazily per decision epoch (dropping the aux), the
    telemetry scan stacks carry + aux per window via ``lax.scan`` ys
    (DESIGN.md §11).  Because commits never start before an already-closed
    window (``start ≥ data_ready ≥ epoch ≥ window end``), replaying the
    windows against the *final* schedule state yields exactly the in-loop
    values — tests pin the replayed peak to the kernel's ``peak_temp_c``.
    """
    opp_idx, next_w, temps, peak = carry
    w1, w0 = next_w, next_w - window
    committed = st["scheduled"] & valid_j                      # (J, T)
    ov = jnp.clip(jnp.minimum(st["finish"], w1)
                  - jnp.maximum(st["start"], w0), 0.0, window)
    ov = jnp.where(committed, ov, 0.0)                         # (J, T)
    dom_oh = jax.nn.one_hot(tables.pe_domain[st["onpe"]],
                            tables.opp_freq.shape[0],
                            dtype=jnp.float32)                 # (J, T, C)
    cpu_w = tables.pe_is_cpu[st["onpe"]]                       # (J, T)
    busy_dom = jnp.einsum("jt,jtc->c", ov * cpu_w, dom_oh)
    util = busy_dom / jnp.maximum(window * tables.domain_cpu, 1e-9)
    proposed = ondemand_index(tables.opp_freq, tables.num_opp, up, util,
                              xp=jnp)
    # realised per-node window power: active at the latched OPP + idle
    P = tables.num_pes
    pe_oh = jax.nn.one_hot(st["onpe"], P, dtype=jnp.float32)   # (J, T, P)
    p_task = tables.power_active_opp[st["onpe"], st["onopp"]]  # (J, T)
    e_act = jnp.einsum("jt,jtp->p", ov * p_task, pe_oh)        # (P,) W·us
    busy_pe = jnp.einsum("jt,jtp->p", ov, pe_oh)
    idle_frac = 1.0 - jnp.clip(busy_pe / window, 0.0, 1.0)
    p_pe = e_act / window + tables.power_idle * idle_frac      # (P,) W
    node_oh = jax.nn.one_hot(tables.node_of_pe, _thermal.NUM_NODES,
                             dtype=jnp.float32)                # (P, 3)
    node_p = p_pe @ node_oh                                    # (3,) W
    temps = _thermal.exact_step_jax(temps, node_p, A_rc, B_rc)
    peak = jnp.maximum(peak, jnp.max(temps[:3]))
    opp_idx = throttle_index(proposed, temps[tables.domain_node], cap,
                             xp=jnp)
    return (opp_idx, next_w + window, temps, peak), (util, node_p)


# --------------------------------------------------------------------------
# The simulation kernel — one epoch-scan, static DVFS as the degenerate case
# --------------------------------------------------------------------------

def _epoch_scan(tables: SimTables, policy: str, num_jobs: int,
                arrival: jnp.ndarray, app_idx: jnp.ndarray,
                gov: Optional[GovernorPolicy],
                faults: Optional[jnp.ndarray] = None,
                scan_steps: Optional[int] = None):
    """Shared epoch-scan body: ``gov=None`` compiles the static-OPP program
    (tables carry the latency/power at the governor's fixed OPP); a dynamic
    ``GovernorPolicy`` closes the DVFS + thermal loop per sampling window.

    ``faults`` (optional, (P,) f32 fail times, ``+inf`` = never — see
    ``repro.scenario.faults.fault_plan``) compiles the fail-stop program
    (DESIGN.md §14): the carry gains a per-PE ``fired`` mask and a per-task
    re-enqueue ``floor``; when an epoch crosses a fail time the dead PE's
    unfinished tasks and their committed descendants roll back inside the
    scan, and the scheduler's argmin excludes dead PEs (graceful
    degradation: accelerator tasks fall back to surviving CPU PEs).
    ``faults=None`` keeps this program byte-identical to the fault-free
    kernel.  ``scan_steps`` (static) bounds the iteration count and is
    required with faults — rollbacks re-commit tasks, so ``J·T`` no longer
    suffices (``repro.scenario.faults.fault_scan_steps``).
    """
    T, P = tables.t_max, tables.num_pes
    J = num_jobs
    dtpm = gov is not None
    faulted = faults is not None
    if faulted and policy == "table":
        raise ValueError(
            "fail-stop injection needs a PE-masking scheduler; the table "
            "policy pins static assignments — use met/etf (DESIGN.md §14)")
    if faulted and scan_steps is None:
        raise ValueError("the faulted scan needs a static scan_steps bound "
                         "(see repro.scenario.faults.fault_scan_steps)")

    pred_j = tables.pred[app_idx]          # (J, T, T)
    ebytes_j = tables.ebytes[app_idx]      # (J, T, T)
    valid_j = tables.valid[app_idx]        # (J, T)
    table_j = tables.table_pe[app_idx]     # (J, T)
    if not dtpm:
        exec_j = tables.exec_us[app_idx]   # (J, T, P)

    # static iteration bound: one commit per real task, plus the rollback
    # re-commits + skip epochs a caller-supplied fault budget adds
    total = J * T if scan_steps is None else scan_steps

    state = dict(
        scheduled=~valid_j,                              # invalid = pre-done
        finish=jnp.zeros((J, T), jnp.float32),
        start=jnp.zeros((J, T), jnp.float32),
        onpe=jnp.zeros((J, T), jnp.int32),
        pe_free=jnp.zeros((P,), jnp.float32),
    )
    if faulted:
        state.update(
            fired=jnp.zeros((P,), bool),                 # PE dead already
            floor=jnp.zeros((J, T), jnp.float32),        # re-enqueue floor
        )
    if dtpm:
        C = tables.opp_freq.shape[0]
        window = jnp.asarray(gov.sample_window_us, jnp.float32)
        up = jnp.asarray(gov.up_threshold, jnp.float32)
        cap = jnp.asarray(gov.thermal_cap_c, jnp.float32)
        # exact per-window RC update (DESIGN.md §6): unconditionally stable
        A_rc, B_rc = _thermal.exact_step_matrices_jax(gov.thermal_dt_s)
        amb = jnp.asarray(_thermal.T_AMBIENT_C, jnp.float32)
        state.update(
            onopp=jnp.zeros((J, T), jnp.int32),          # OPP latched at commit
            opp_idx=jnp.zeros((C,), jnp.int32),          # ondemand starts at fmin
            next_w=window,
            temps=jnp.full((4,), _thermal.T_AMBIENT_C, jnp.float32),
            peak_t=amb,
        )

    flat_order = (jnp.arange(J, dtype=jnp.int32)[:, None] * T
                  + jnp.arange(T, dtype=jnp.int32)[None, :])      # (J, T)

    def advance_window(st, carry):
        """Advance one sampling window; the telemetry aux is dropped here
        (dead code the compiler eliminates — the program is unchanged)."""
        return _window_step(tables, valid_j, window, up, cap, A_rc, B_rc,
                            st, carry)[0]

    def apply_faults(st, fire):
        """Fail-stop rollback (the in-scan twin of the reference kernel's
        ``apply_failure``): invalidate unfinished tasks on the PEs firing
        now plus their committed-descendant closure, reset their records,
        recompute the queue drain times from the surviving schedule, and
        floor direct victims at the fail time (descendants and tasks whose
        pred was lost re-ready off their preds' fresh finish times)."""
        committed = st["scheduled"] & valid_j
        onpe, fin = st["onpe"], st["finish"]
        ftime = faults[onpe]                                       # (J, T)
        inv = committed & fire[onpe] & (fin > ftime)
        closure = lambda _, acc: acc | (
            committed & jnp.any(pred_j & acc[:, None, :], axis=-1))
        inv = jax.lax.fori_loop(0, T, closure, inv)
        any_pred_inv = jnp.any(pred_j & inv[:, None, :], axis=-1)  # (J, T)
        roots = inv & ~any_pred_inv                # all preds still committed
        sched2 = st["scheduled"] & ~inv
        fin2 = jnp.where(inv, 0.0, fin)
        recomputed = jnp.zeros((P,), jnp.float32).at[onpe].max(
            jnp.where(sched2 & valid_j, fin2, 0.0))
        new = dict(
            st,
            scheduled=sched2,
            finish=fin2,
            start=jnp.where(inv, 0.0, st["start"]),
            onpe=jnp.where(inv, 0, onpe),
            pe_free=jnp.where(jnp.any(inv), recomputed, st["pe_free"]),
            fired=st["fired"] | fire,
            floor=jnp.where(roots, ftime,
                            jnp.where(any_pred_inv, 0.0, st["floor"])),
        )
        if dtpm:
            new["onopp"] = jnp.where(inv, 0, st["onopp"])
        return new

    def body(st, _):
        scheduled, finish = st["scheduled"], st["finish"]
        # 1. eligibility: job tasks whose preds are all committed
        preds_open = jnp.any(pred_j & ~scheduled[:, None, :], axis=-1)   # (J, T)
        eligible = (~scheduled) & (~preds_open)
        # 2. epoch time (no comm): max(arrival, max pred finish); rolled-back
        # direct fault victims additionally wait out the fail time (floor)
        pf = jnp.where(pred_j, finish[:, None, :], -BIG)                  # (J,T,T)
        ready = jnp.maximum(arrival[:, None], jnp.max(pf, axis=-1))      # (J, T)
        if faulted:
            ready = jnp.maximum(ready, st["floor"])
        ready = jnp.where(eligible, ready, BIG)
        # 3. lexicographic argmin (ready, job, task)
        rmin = jnp.min(ready)
        tie = eligible & (ready <= rmin)
        pick = jnp.min(jnp.where(tie, flat_order, jnp.int32(2**30)))
        j, t = pick // T, pick % T
        any_left = rmin < BIG * 0.5

        # 3a. fail-stop events this epoch crosses fire before anything else
        # (the reference kernel triggers them at heap pop); the pick then
        # goes stale exactly when the rollback took one of its preds — that
        # epoch is skipped, like the oracle's stale heap entries
        if faulted:
            fire = (~st["fired"]) & (faults <= rmin) & any_left
            st = jax.lax.cond(jnp.any(fire), apply_faults,
                              lambda s, _f: s, st, fire)
            skip = jnp.any(pred_j[j, t] & ~st["scheduled"][j])
            do_commit = any_left & ~skip
        else:
            do_commit = any_left

        # 3b. DVFS windows elapsed before this epoch close the loop: the
        # governor transition + thermal feedback run, then latency re-indexes
        if dtpm:
            now = jnp.where(do_commit, rmin, -BIG)
            opp_idx, next_w, temps, peak = jax.lax.while_loop(
                lambda c: c[1] <= now,
                functools.partial(advance_window, st),
                (st["opp_idx"], st["next_w"], st["temps"], st["peak_t"]))
            st = dict(st, opp_idx=opp_idx, next_w=next_w, temps=temps,
                      peak_t=peak)
            opp_of_pe = opp_idx[tables.pe_domain]                     # (P,)
            ex = tables.exec_opp[app_idx[j], t][jnp.arange(P), opp_of_pe]
        else:
            ex = exec_j[j, t]                                         # (P,)

        # 4. per-PE data-ready with comm from producer PEs
        onpe_row = st["onpe"][j]                                        # (T,)
        mult = tables.comm_mult[onpe_row]                               # (T, P)
        base = tables.comm_startup + ebytes_j[j, t] * tables.comm_inv_bw  # (T,)
        comm = mult * base[:, None]                                     # (T, P)
        pf_row = jnp.where(pred_j[j, t], st["finish"][j], -BIG)         # (T,)
        data_ready = jnp.maximum(
            rmin, jnp.max(pf_row[:, None] + comm, axis=0))              # (P,)
        start_c = jnp.maximum(data_ready, st["pe_free"])                # (P,)
        fin_c = start_c + ex                                            # (P,)

        # 5. policy — dead PEs are excluded from the argmin the same way the
        # reference schedulers apply ctx.available (np.inf candidates), NOT
        # via pe_free: the oracle skips its pe_free recompute when a fault
        # invalidates nothing, so the mask is the only exclusion channel
        if policy == "etf":
            cand = jnp.where(st["fired"], jnp.inf, fin_c) if faulted else fin_c
            pe = jnp.argmin(cand).astype(jnp.int32)
        elif policy == "met":
            # canonical MET: min execution time, availability ignored
            # (DVFS-scaled at the current OPP, matching the reference)
            cand = jnp.where(st["fired"], jnp.inf, ex) if faulted else ex
            pe = jnp.argmin(cand).astype(jnp.int32)
        elif policy == "table":
            pe = table_j[j, t]
        else:
            raise ValueError(f"unknown policy {policy!r}")

        # 6. commit (no-op when nothing eligible — padding iterations)
        s0 = jnp.maximum(data_ready[pe], st["pe_free"][pe])
        f0 = s0 + ex[pe]

        def commit(st):
            new = dict(
                st,
                scheduled=st["scheduled"].at[j, t].set(True),
                finish=st["finish"].at[j, t].set(f0),
                start=st["start"].at[j, t].set(s0),
                onpe=st["onpe"].at[j, t].set(pe),
                pe_free=st["pe_free"].at[pe].set(f0),
            )
            if dtpm:
                new["onopp"] = st["onopp"].at[j, t].set(opp_of_pe[pe])
            return new

        return jax.lax.cond(do_commit, commit, lambda s: s, st), None

    st, _ = jax.lax.scan(body, state, None, length=total)

    busy = st["finish"] - st["start"]                                   # (J, T)
    makespan = jnp.max(jnp.where(valid_j, st["finish"], 0.0))
    if dtpm:
        # drain the windows between the last decision epoch and the makespan
        # so peak_temp_c covers the schedule's execution tail, including the
        # final partial window (its start precedes the makespan; no further
        # commits happen, so this cannot perturb ref<->jax schedule parity)
        opp_idx, next_w, temps, peak = jax.lax.while_loop(
            lambda c: c[1] - window < makespan,
            functools.partial(advance_window, st),
            (st["opp_idx"], st["next_w"], st["temps"], st["peak_t"]))
        st = dict(st, opp_idx=opp_idx, next_w=next_w, temps=temps,
                  peak_t=peak)
    job_finish = jnp.max(jnp.where(valid_j, st["finish"], 0.0), axis=1)
    avg_latency = jnp.mean(job_finish - arrival)
    # energy: active while busy + idle leakage elsewhere  (uJ = W * us)
    onpe_oh = (jax.nn.one_hot(st["onpe"], tables.num_pes, dtype=jnp.float32)
               * jnp.where(valid_j, 1.0, 0.0)[..., None])
    if dtpm:
        p_task = tables.power_active_opp[st["onpe"], st["onopp"]]       # (J, T)
        e_active = jnp.sum(jnp.where(valid_j, busy * p_task, 0.0))
    else:
        e_active = jnp.sum(jnp.where(valid_j, busy, 0.0)[..., None]
                           * onpe_oh * tables.power_active[None, None, :])
    busy_per_pe = jnp.sum(
        jnp.where(valid_j, busy, 0.0)[..., None] * onpe_oh, axis=(0, 1))
    e_idle = jnp.sum(tables.power_idle * jnp.maximum(makespan - busy_per_pe, 0.0))
    energy_j = (e_active + e_idle) * 1e-6                # W·us -> J

    out = dict(
        finish=st["finish"], start=st["start"], onpe=st["onpe"],
        scheduled=st["scheduled"], job_finish=job_finish,
        makespan_us=makespan, avg_job_latency_us=avg_latency,
        energy_j=energy_j, busy_per_pe_us=busy_per_pe,
    )
    if dtpm:
        out.update(onopp=st["onopp"], opp_idx=st["opp_idx"],
                   peak_temp_c=st["peak_t"])
    return out


@functools.partial(jax.jit,
                   static_argnames=("policy", "num_jobs", "scan_steps"))
def _simulate(tables: SimTables, policy: str, num_jobs: int,
              arrival: jnp.ndarray, app_idx: jnp.ndarray,
              faults: Optional[jnp.ndarray] = None,
              scan_steps: Optional[int] = None):
    if tables.exec_opp is not None:
        # dynamic-built tables bake exec_us at the governor's initial (fmin)
        # OPP — the static kernel would return plausible but wrong numbers
        raise ValueError("tables were built for a dynamic governor; run "
                         "them through simulate_jax_dtpm (DESIGN.md §7)")
    _COMPILES_STATIC.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    return _epoch_scan(tables, policy, num_jobs, arrival, app_idx, None,
                       faults, scan_steps)


@functools.partial(jax.jit,
                   static_argnames=("policy", "num_jobs", "scan_steps"))
def _simulate_dtpm(tables: SimTables, policy: str, num_jobs: int,
                   arrival: jnp.ndarray, app_idx: jnp.ndarray,
                   gov: GovernorPolicy,
                   faults: Optional[jnp.ndarray] = None,
                   scan_steps: Optional[int] = None):
    if tables.exec_opp is None:
        raise ValueError("tables lack OPP ladders; build them with the "
                         "dynamic governor (build_tables(governor=...))")
    _COMPILES_DTPM.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    return _epoch_scan(tables, policy, num_jobs, arrival, app_idx, gov,
                       faults, scan_steps)


def _fault_steps(num_jobs: int, t_max: int, faults) -> int:
    """Static scan bound for a concrete (P,) fault plan: every fault may
    roll back all J·T committed tasks and costs one skipped epoch."""
    n = int(np.isfinite(np.asarray(faults)).sum())
    return num_jobs * t_max * (1 + n) + n


def simulate_jax(tables: SimTables, policy: str, arrival: np.ndarray,
                 app_idx: np.ndarray, faults=None):
    """Single simulation.  ``arrival``: (J,) f32; ``app_idx``: (J,) i32.

    ``faults``: optional (P,) fail-time plan (f32, ``+inf`` = never fails;
    see ``repro.scenario.faults.fault_plan``) — compiles the fail-stop
    program (DESIGN.md §14), bit-for-bit equal to the reference kernel's
    rollback semantics on comm-free traces.
    """
    J = int(arrival.shape[0])
    if faults is None:
        return _simulate(tables, policy, J,
                         jnp.asarray(arrival, jnp.float32),
                         jnp.asarray(app_idx, jnp.int32))
    return _simulate(tables, policy, J,
                     jnp.asarray(arrival, jnp.float32),
                     jnp.asarray(app_idx, jnp.int32),
                     jnp.asarray(faults, jnp.float32),
                     scan_steps=_fault_steps(J, tables.t_max, faults))


def simulate_jax_dtpm(tables: SimTables, policy: str, arrival: np.ndarray,
                      app_idx: np.ndarray, gov: GovernorPolicy,
                      faults=None):
    """Single closed-loop DTPM simulation under a dynamic governor policy.

    The output dict gains ``onopp`` (the OPP index latched per task),
    ``opp_idx`` (final per-domain OPP) and ``peak_temp_c`` — the peak on-chip
    temperature from the inline RC loop the throttle feedback integrates.
    Windows advance lazily at decision epochs (mirroring the reference
    kernel), then drain to the makespan after the last commit so the peak
    covers the schedule's execution tail; throttle decisions during the
    drain are moot (nothing is left to schedule).
    """
    if not gov.dynamic:
        raise ValueError("static governors bake into the tables; use "
                         "simulate_jax (DESIGN.md §7)")
    validate_policy_params(gov.sample_window_us, gov.up_threshold,
                           gov.thermal_dt_s)
    J = int(arrival.shape[0])
    if faults is None:
        return _simulate_dtpm(tables, policy, J,
                              jnp.asarray(arrival, jnp.float32),
                              jnp.asarray(app_idx, jnp.int32), gov)
    return _simulate_dtpm(tables, policy, J,
                          jnp.asarray(arrival, jnp.float32),
                          jnp.asarray(app_idx, jnp.int32), gov,
                          jnp.asarray(faults, jnp.float32),
                          scan_steps=_fault_steps(J, tables.t_max, faults))


def simulate_batch(tables: SimTables, policy: str, arrival: np.ndarray,
                   app_idx: np.ndarray):
    """Batched simulation: ``arrival``/(B, J), ``app_idx``/(B, J) — one design
    point per row (seed × rate × mix).  Runs as ONE vmapped tensor program."""
    fn = jax.vmap(lambda a, i: _simulate(tables, policy, int(arrival.shape[1]), a, i))
    return fn(jnp.asarray(arrival, jnp.float32), jnp.asarray(app_idx, jnp.int32))


# --------------------------------------------------------------------------
# Telemetry scans — per-window (W, C) timelines from a realised schedule
# --------------------------------------------------------------------------
#
# Both scans replay the kernel's window machinery against the *final* epoch
# scan state.  For the DTPM kernel this is value-identical to the in-loop
# carry (see _window_step's docstring: no commit can overlap a closed
# window), so telemetry costs one extra small program and the simulation
# program itself — the telemetry=False path — stays byte-identical.

@functools.partial(jax.jit, static_argnames=("num_windows",))
def _telemetry_scan_dtpm(tables: SimTables, gov: GovernorPolicy,
                         app_idx, scheduled, start, finish, onpe, onopp,
                         num_windows: int):
    """(W, …) ys of the DTPM window carry: OPP index, utilisation, node
    power and RC temperatures per sampling window."""
    _COMPILES_TELEMETRY.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    valid_j = tables.valid[app_idx]
    C = tables.opp_freq.shape[0]
    window = jnp.asarray(gov.sample_window_us, jnp.float32)
    up = jnp.asarray(gov.up_threshold, jnp.float32)
    cap = jnp.asarray(gov.thermal_cap_c, jnp.float32)
    A_rc, B_rc = _thermal.exact_step_matrices_jax(gov.thermal_dt_s)
    st = dict(scheduled=scheduled, start=start, finish=finish,
              onpe=onpe, onopp=onopp)
    step = functools.partial(_window_step, tables, valid_j, window, up, cap,
                             A_rc, B_rc, st)
    carry0 = (jnp.zeros((C,), jnp.int32), window,
              jnp.full((4,), _thermal.T_AMBIENT_C, jnp.float32),
              jnp.float32(_thermal.T_AMBIENT_C))

    def body(carry, _):
        new, (util, node_p) = step(carry)
        return new, dict(opp_idx=new[0], util=util, power_w=node_p,
                         temps_c=new[2])

    _, ys = jax.lax.scan(body, carry0, None, length=num_windows)
    return ys


@functools.partial(jax.jit, static_argnames=("num_windows", "num_domains"))
def _telemetry_scan_static(tables: SimTables, app_idx, scheduled, start,
                           finish, onpe, window_us, num_windows: int,
                           num_domains: int):
    """Static-governor telemetry: same window observables at the tables'
    fixed OPP (frequency columns are filled by the caller — they are
    constants of the governor, not of the schedule).  The RC network
    integrates in real time (dt = window)."""
    _COMPILES_TELEMETRY.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    valid_j = tables.valid[app_idx]
    P = tables.num_pes
    C = num_domains
    window = jnp.asarray(window_us, jnp.float32)
    A_rc, B_rc = _thermal.exact_step_matrices_jax(window * 1e-6)
    committed = scheduled & valid_j
    dom_oh = jax.nn.one_hot(tables.pe_domain[onpe], C, dtype=jnp.float32)
    cpu_w = tables.pe_is_cpu[onpe]
    pe_oh = jax.nn.one_hot(onpe, P, dtype=jnp.float32)
    node_oh = jax.nn.one_hot(tables.node_of_pe, _thermal.NUM_NODES,
                             dtype=jnp.float32)
    domain_cpu = jnp.zeros((C,), jnp.float32).at[tables.pe_domain].add(
        tables.pe_is_cpu)
    p_task = tables.power_active[onpe]                         # (J, T)

    def body(carry, w):
        temps = carry
        w0 = w.astype(jnp.float32) * window
        w1 = w0 + window
        ov = jnp.clip(jnp.minimum(finish, w1) - jnp.maximum(start, w0),
                      0.0, window)
        ov = jnp.where(committed, ov, 0.0)
        busy_dom = jnp.einsum("jt,jtc->c", ov * cpu_w, dom_oh)
        util = busy_dom / jnp.maximum(window * domain_cpu, 1e-9)
        e_act = jnp.einsum("jt,jtp->p", ov * p_task, pe_oh)
        busy_pe = jnp.einsum("jt,jtp->p", ov, pe_oh)
        idle_frac = 1.0 - jnp.clip(busy_pe / window, 0.0, 1.0)
        p_pe = e_act / window + tables.power_idle * idle_frac
        node_p = p_pe @ node_oh
        temps = _thermal.exact_step_jax(temps, node_p, A_rc, B_rc)
        return temps, dict(util=util, power_w=node_p, temps_c=temps)

    _, ys = jax.lax.scan(body, jnp.full((4,), _thermal.T_AMBIENT_C,
                                        jnp.float32),
                         jnp.arange(num_windows))
    return ys
