"""Vectorised JAX simulation kernel — batched design-space exploration.

The paper's speed story (system-level simulation ~600× faster than cycle
accurate gem5) is re-thought for accelerators: instead of making *one*
event-heap simulation fast, the whole simulator becomes a fixed-shape tensor
program (``lax.fori_loop`` over decision epochs + masked argmin selects) so
that **thousands of simulations — seeds × injection rates × SoC configs ×
schedulers — run batched under ``vmap``/``jit``**.

Semantics are identical to ``simkernel_ref`` (same epoch ordering, same
tie-breaking, float32 arithmetic): the two kernels are cross-validated in
``tests/test_sim_equivalence.py``.

Supported here: MET / ETF / table schedulers and *static* DVFS governors
(performance / powersave / userspace).  The window-sampled ondemand governor
needs data-dependent re-profiling and lives in the reference kernel only
(see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .applications import Application
from .dvfs import Governor, PerformanceGovernor
from .power import active_power, idle_power
from .resources import NOMINAL_FREQ, ResourceDB

BIG = jnp.float32(1e30)


# --------------------------------------------------------------------------
# Static tables (device-resident constants per (db, apps, governor) triple)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimTables:
    exec_us: jnp.ndarray        # (A, T, P) f32 — DVFS-scaled latency, BIG=unsupported
    pred: jnp.ndarray           # (A, T, T) bool
    ebytes: jnp.ndarray         # (A, T, T) f32 (bytes flowing t' -> t)
    valid: jnp.ndarray          # (A, T) bool
    comm_mult: jnp.ndarray      # (P, P) f32 in {0,1,penalty}
    comm_startup: jnp.ndarray   # () f32
    comm_inv_bw: jnp.ndarray    # () f32
    power_active: jnp.ndarray   # (P,) f32  W while busy
    power_idle: jnp.ndarray     # (P,) f32  W while idle
    table_pe: jnp.ndarray       # (A, T) i32 — table-scheduler assignment (or -1)
    t_max: int
    num_pes: int


jax.tree_util.register_dataclass(
    SimTables,
    data_fields=["exec_us", "pred", "ebytes", "valid", "comm_mult",
                 "comm_startup", "comm_inv_bw", "power_active", "power_idle",
                 "table_pe"],
    meta_fields=["t_max", "num_pes"],
)


def build_tables(db: ResourceDB, apps: Sequence[Application],
                 governor: Optional[Governor] = None,
                 table: Optional[Dict[Tuple[str, int], int]] = None,
                 pad_tasks: Optional[int] = None,
                 pad_pes: Optional[int] = None) -> SimTables:
    """Build device-resident simulation tables for one SoC design.

    ``pad_tasks`` / ``pad_pes`` pad the task and PE axes to a fixed size so
    tables from *different* designs stack into one (D, …) batch (see
    ``repro.dse.batch``).  Padding is inert by construction: padded task rows
    are invalid (pre-scheduled), padded PE columns carry BIG latency (never
    win an argmin) and zero active/idle power (no energy contribution).
    """
    governor = governor or PerformanceGovernor()
    A = len(apps)
    T = max(a.num_tasks for a in apps)
    P = db.num_pes
    if pad_tasks is not None:
        if pad_tasks < T:
            raise ValueError(f"pad_tasks={pad_tasks} < max tasks {T}")
        T = pad_tasks
    if pad_pes is not None:
        if pad_pes < P:
            raise ValueError(f"pad_pes={pad_pes} < num_pes {P}")
        P = pad_pes

    freq = {}
    for pe in db.pes:
        if pe.is_cpu and pe.cluster not in freq:
            freq[pe.cluster] = governor.initial_freq(pe.pe_type)

    exec_us = np.full((A, T, P), 1e30, dtype=np.float32)
    pred = np.zeros((A, T, T), dtype=bool)
    ebytes = np.zeros((A, T, T), dtype=np.float32)
    valid = np.zeros((A, T), dtype=bool)
    table_pe = np.full((A, T), -1, dtype=np.int32)

    for ai, app in enumerate(apps):
        lat = db.latency_matrix(app.task_names)      # (t, P), inf unsupported
        for t in range(app.num_tasks):
            valid[ai, t] = True
            for j, pe in enumerate(db.pes):
                base = lat[t, j]
                if np.isfinite(base):
                    scale = (NOMINAL_FREQ[pe.pe_type] / freq[pe.cluster]
                             if pe.is_cpu else 1.0)
                    exec_us[ai, t, j] = np.float32(np.float32(base) * np.float32(scale))
            if table is not None:
                table_pe[ai, t] = table.get((app.name, t), -1)
        pred[ai, :app.num_tasks, :app.num_tasks] = app.pred_matrix()
        ebytes[ai, :app.num_tasks, :app.num_tasks] = app.edge_bytes_matrix()

    comm_mult = np.zeros((P, P), dtype=np.float32)
    for s in range(db.num_pes):
        for d in range(db.num_pes):
            if s == d:
                continue
            comm_mult[s, d] = (db.comm.cross_cluster_penalty
                               if db.pes[s].cluster != db.pes[d].cluster else 1.0)

    p_act = np.zeros(P, dtype=np.float32)
    p_idle = np.zeros(P, dtype=np.float32)
    for j, pe in enumerate(db.pes):
        f = freq.get(pe.cluster, 0.0) if pe.is_cpu else 0.0
        p_act[j] = active_power(pe, f)
        p_idle[j] = idle_power(pe)

    return SimTables(
        exec_us=jnp.asarray(exec_us),
        pred=jnp.asarray(pred), ebytes=jnp.asarray(ebytes),
        valid=jnp.asarray(valid),
        comm_mult=jnp.asarray(comm_mult),
        comm_startup=jnp.float32(db.comm.startup_us),
        comm_inv_bw=jnp.float32(1.0 / db.comm.bw_bytes_per_us),
        power_active=jnp.asarray(p_act), power_idle=jnp.asarray(p_idle),
        table_pe=jnp.asarray(table_pe), t_max=T, num_pes=P)


# --------------------------------------------------------------------------
# The simulation kernel
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy", "num_jobs"))
def _simulate(tables: SimTables, policy: str, num_jobs: int,
              arrival: jnp.ndarray, app_idx: jnp.ndarray):
    T, P = tables.t_max, tables.num_pes
    J = num_jobs

    pred_j = tables.pred[app_idx]          # (J, T, T)
    ebytes_j = tables.ebytes[app_idx]      # (J, T, T)
    valid_j = tables.valid[app_idx]        # (J, T)
    exec_j = tables.exec_us[app_idx]       # (J, T, P)
    table_j = tables.table_pe[app_idx]     # (J, T)

    total = J * T  # static iteration bound: one commit per real task

    state = dict(
        scheduled=~valid_j,                              # invalid = pre-done
        finish=jnp.zeros((J, T), jnp.float32),
        start=jnp.zeros((J, T), jnp.float32),
        onpe=jnp.zeros((J, T), jnp.int32),
        pe_free=jnp.zeros((P,), jnp.float32),
    )

    job_ids = jnp.arange(J, dtype=jnp.int32)
    flat_order = (jnp.arange(J, dtype=jnp.int32)[:, None] * T
                  + jnp.arange(T, dtype=jnp.int32)[None, :])      # (J, T)

    def body(_, st):
        scheduled, finish = st["scheduled"], st["finish"]
        # 1. eligibility: job tasks whose preds are all committed
        preds_open = jnp.any(pred_j & ~scheduled[:, None, :], axis=-1)   # (J, T)
        eligible = (~scheduled) & (~preds_open)
        # 2. epoch time (no comm): max(arrival, max pred finish)
        pf = jnp.where(pred_j, finish[:, None, :], -BIG)                  # (J,T,T)
        ready = jnp.maximum(arrival[:, None], jnp.max(pf, axis=-1))      # (J, T)
        ready = jnp.where(eligible, ready, BIG)
        # 3. lexicographic argmin (ready, job, task)
        rmin = jnp.min(ready)
        tie = eligible & (ready <= rmin)
        pick = jnp.min(jnp.where(tie, flat_order, jnp.int32(2**30)))
        j, t = pick // T, pick % T
        any_left = rmin < BIG * 0.5

        # 4. per-PE data-ready with comm from producer PEs
        onpe_row = st["onpe"][j]                                        # (T,)
        mult = tables.comm_mult[onpe_row]                               # (T, P)
        base = tables.comm_startup + ebytes_j[j, t] * tables.comm_inv_bw  # (T,)
        comm = mult * base[:, None]                                     # (T, P)
        pf_row = jnp.where(pred_j[j, t], finish[j], -BIG)               # (T,)
        data_ready = jnp.maximum(
            rmin, jnp.max(pf_row[:, None] + comm, axis=0))              # (P,)
        start_c = jnp.maximum(data_ready, st["pe_free"])                # (P,)
        fin_c = start_c + exec_j[j, t]                                  # (P,)

        # 5. policy
        if policy == "etf":
            pe = jnp.argmin(fin_c).astype(jnp.int32)
        elif policy == "met":
            # canonical MET: min execution time, availability ignored
            ex = exec_j[j, t]   # DVFS-scaled, matching the reference scheduler
            pe = jnp.argmin(ex).astype(jnp.int32)
        elif policy == "table":
            pe = table_j[j, t]
        else:
            raise ValueError(f"unknown policy {policy!r}")

        # 6. commit (no-op when nothing eligible — padding iterations)
        s0 = jnp.maximum(data_ready[pe], st["pe_free"][pe])
        f0 = s0 + exec_j[j, t, pe]

        def commit(st):
            return dict(
                scheduled=st["scheduled"].at[j, t].set(True),
                finish=st["finish"].at[j, t].set(f0),
                start=st["start"].at[j, t].set(s0),
                onpe=st["onpe"].at[j, t].set(pe),
                pe_free=st["pe_free"].at[pe].set(f0),
            )

        return jax.lax.cond(any_left, commit, lambda s: s, st)

    st = jax.lax.fori_loop(0, total, body, state)

    busy = st["finish"] - st["start"]                                   # (J, T)
    makespan = jnp.max(jnp.where(valid_j, st["finish"], 0.0))
    job_finish = jnp.max(jnp.where(valid_j, st["finish"], 0.0), axis=1)
    avg_latency = jnp.mean(job_finish - arrival)
    # energy: active while busy + idle leakage elsewhere  (uJ = W * us)
    e_active = jnp.sum(
        jnp.where(valid_j, busy, 0.0)[..., None]
        * (jax.nn.one_hot(st["onpe"], tables.num_pes, dtype=jnp.float32)
           * jnp.where(valid_j, 1.0, 0.0)[..., None])
        * tables.power_active[None, None, :])
    busy_per_pe = jnp.sum(
        jnp.where(valid_j, busy, 0.0)[..., None]
        * jax.nn.one_hot(st["onpe"], tables.num_pes, dtype=jnp.float32)
        * jnp.where(valid_j, 1.0, 0.0)[..., None], axis=(0, 1))
    e_idle = jnp.sum(tables.power_idle * jnp.maximum(makespan - busy_per_pe, 0.0))
    energy_j = (e_active + e_idle) * 1e-6                # W·us -> J

    return dict(
        finish=st["finish"], start=st["start"], onpe=st["onpe"],
        scheduled=st["scheduled"], job_finish=job_finish,
        makespan_us=makespan, avg_job_latency_us=avg_latency,
        energy_j=energy_j, busy_per_pe_us=busy_per_pe,
    )


def simulate_jax(tables: SimTables, policy: str, arrival: np.ndarray,
                 app_idx: np.ndarray):
    """Single simulation.  ``arrival``: (J,) f32; ``app_idx``: (J,) i32."""
    return _simulate(tables, policy, int(arrival.shape[0]),
                     jnp.asarray(arrival, jnp.float32),
                     jnp.asarray(app_idx, jnp.int32))


def simulate_batch(tables: SimTables, policy: str, arrival: np.ndarray,
                   app_idx: np.ndarray):
    """Batched simulation: ``arrival``/(B, J), ``app_idx``/(B, J) — one design
    point per row (seed × rate × mix).  Runs as ONE vmapped tensor program."""
    fn = jax.vmap(lambda a, i: _simulate(tables, policy, int(arrival.shape[1]), a, i))
    return fn(jnp.asarray(arrival, jnp.float32), jnp.asarray(app_idx, jnp.int32))
