"""Reference discrete-event simulation kernel (the oracle).

Semantics (DS3-style, matching the paper §2):

* The job generator injects application instances at given arrival times.
* A task reaches its *decision epoch* when its job has arrived and all its
  predecessors have been committed; the epoch time is
  ``max(arrival, max_p finish_p)`` (communication cost is accounted per
  candidate PE inside the scheduler, not in the epoch time).
* At each epoch the framework invokes the pluggable scheduler with the ready
  task; the scheduler picks a PE; the task enters that PE's FIFO queue:
  ``start = max(ready_on_pe(incl. comm), pe_free)``, ``finish = start + exec``.
* CPU execution time scales with the cluster's DVFS frequency (latched at
  task start); accelerators run at fixed clocks.
* Power/energy are integrated over the realised schedule; an optional
  ondemand governor updates cluster frequencies on sampling-window
  boundaries from measured utilisation.

Epoch ordering (and all tie-breaking) is deterministic:
(ready_time, job_id, task_id) — the vectorised JAX kernel replicates it
bit-for-bit so the two kernels can be cross-validated.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .applications import Application
from .dvfs import Governor, PerformanceGovernor, capped_levels, throttle_index
from .jobgen import JobTrace
from .power import EnergyReport, active_power, energy_from_schedule, idle_power
from .resources import CPU_TYPES, NOMINAL_FREQ, PE, ResourceDB
from .schedulers import SchedContext, Scheduler
from . import thermal as _thermal


@dataclasses.dataclass
class TaskRecord:
    job_id: int
    task_id: int
    pe_id: int
    ready_us: float
    start_us: float
    finish_us: float
    freq_ghz: float


@dataclasses.dataclass
class SimResult:
    records: List[TaskRecord]
    job_arrival_us: np.ndarray
    job_finish_us: np.ndarray
    makespan_us: float
    energy: EnergyReport

    @property
    def avg_job_latency_us(self) -> float:
        return float(np.mean(self.job_finish_us - self.job_arrival_us))

    @property
    def throughput_jobs_per_ms(self) -> float:
        return len(self.job_finish_us) / max(self.makespan_us, 1e-9) * 1000.0

    def pe_utilization(self, db: ResourceDB) -> np.ndarray:
        busy = np.zeros(db.num_pes)
        for r in self.records:
            busy[r.pe_id] += r.finish_us - r.start_us
        return busy / max(self.makespan_us, 1e-9)


def _fail_times(failures) -> Dict[int, float]:
    """Per-PE fail time, last-wins.  Accepts ``(pe_id, fail_time_us)``
    pairs or ``repro.scenario.FaultSpec`` objects (duck-typed on the
    ``pe_id`` attribute — core must not import the scenario facade)."""
    out: Dict[int, float] = {}
    for f in failures or []:
        if hasattr(f, "pe_id"):
            out[int(f.pe_id)] = float(f.fail_time_us)
        else:
            p, t = f
            out[int(p)] = float(t)
    return {p: t for p, t in out.items() if np.isfinite(t)}


def simulate(db: ResourceDB, apps: Sequence[Application], trace: JobTrace,
             scheduler: Scheduler, governor: Optional[Governor] = None,
             failures: Optional[Sequence[Tuple[int, float]]] = None,
             telemetry=None) -> SimResult:
    """Run one simulation; returns the full schedule + aggregate stats.

    ``failures``: optional fail-stop events — ``FaultSpec`` objects or bare
    ``(pe_id, fail_time_us)`` pairs — at fail time the PE dies permanently;
    tasks in flight or queued on it (and their already-committed
    descendants) are rolled back and re-scheduled on the surviving PEs.
    Models node loss the same way the pod-scale half handles preemption
    (checkpoint/restart): the work is lost, the workload still completes.

    ``telemetry``: optional per-window recorder (duck-typed:
    ``repro.obs.telemetry.TelemetryRecorder``).  Under a dynamic governor
    every sampling window's utilisation, post-transition frequency, realised
    node power and RC temperatures are recorded in-loop — the exact values
    the governor feedback integrated — and the windows are drained past the
    last decision epoch to the makespan (matching the JAX kernel's tail
    drain).  Recording is observation-only: it adds a thermal read-out for
    uncapped governors but never feeds back into scheduling, so results are
    unchanged (asserted in tests/test_obs.py).
    """
    governor = governor or PerformanceGovernor()
    scheduler.reset()

    n_pes = db.num_pes
    pe_free = np.zeros(n_pes, dtype=np.float32)
    fail_at = _fail_times(failures)
    failed: set = set()

    # cluster DVFS state (cluster id -> freq); accelerators fixed
    clusters = sorted({pe.cluster for pe in db.pes if pe.is_cpu})
    cl_type = {c: next(pe.pe_type for pe in db.pes if pe.cluster == c and pe.is_cpu)
               for c in clusters}
    freq = {c: governor.initial_freq(cl_type[c]) for c in clusters}

    def freq_scale_vec() -> np.ndarray:
        out = np.ones(n_pes, dtype=np.float32)
        for j, pe in enumerate(db.pes):
            if pe.is_cpu:
                out[j] = NOMINAL_FREQ[pe.pe_type] / freq[pe.cluster]
        return out

    # ondemand / DTPM bookkeeping — semantics shared with the JAX kernel via
    # the array-form GovernorPolicy (governor.update delegates to
    # dvfs.ondemand_index; the throttle calls dvfs.throttle_index)
    pol = governor.policy()
    window_us = (pol.sample_window_us if pol.dynamic
                 else getattr(governor, "sample_window_us", None))
    next_window_end = window_us if window_us else np.inf
    committed: List[TaskRecord] = []

    throttle = pol.dynamic and np.isfinite(pol.thermal_cap_c)
    recording = telemetry is not None and pol.dynamic and window_us
    caps = getattr(governor, "freq_caps", None)
    # loop invariants of the per-window scans, hoisted: CPU PEs per cluster,
    # capped OPP ladders, thermal node maps.  Recording needs the thermal
    # read-out (and the ladders, for frequency indices) even when no cap is
    # set — the DTPM carry in the JAX kernel always integrates it.
    cl_pes = {c: [pe.pe_id for pe in db.pes
                  if pe.cluster == c and pe.is_cpu] for c in clusters}
    if throttle or recording:
        rc_ab = _thermal.exact_step_matrices(pol.thermal_dt_s)
        temps = np.full(4, _thermal.T_AMBIENT_C)
        node_of_pe = _thermal.cluster_nodes(db)
        cl_node = {c: int(node_of_pe[cl_pes[c][0]]) for c in clusters}
        cl_opps = {c: capped_levels(cl_type[c], caps) for c in clusters}

    def window_util(cluster: int, w0: float, w1: float) -> float:
        pes_in = cl_pes[cluster]
        busy = 0.0
        for r in committed:
            if r.pe_id in pes_in:
                busy += max(0.0, min(r.finish_us, w1) - max(r.start_us, w0))
        return busy / max((w1 - w0) * len(pes_in), 1e-9)

    def window_node_power(w0: float, w1: float) -> np.ndarray:
        """Realised per-thermal-node power (W) over one sampling window:
        active at each task's latched frequency, idle leakage elsewhere."""
        p = np.zeros(_thermal.NUM_NODES)
        busy = np.zeros(n_pes)
        width = w1 - w0
        for r in committed:
            ov = max(0.0, min(r.finish_us, w1) - max(r.start_us, w0))
            if ov <= 0.0:
                continue
            pe = db.pes[r.pe_id]
            p[node_of_pe[r.pe_id]] += active_power(pe, r.freq_ghz) * ov / width
            busy[r.pe_id] += ov
        for j, pe in enumerate(db.pes):
            idle_frac = 1.0 - min(max(busy[j] / width, 0.0), 1.0)
            p[node_of_pe[j]] += idle_power(pe) * idle_frac
        return p

    def nearest_level(cluster: int, f: float) -> int:
        # nearest-level handoff (update() returns a ladder entry)
        opps = cl_opps[cluster]
        return min(range(len(opps)), key=lambda i: abs(opps[i] - f))

    def advance_windows(now: float) -> None:
        nonlocal next_window_end, temps
        while window_us and next_window_end <= now:
            w0 = next_window_end - window_us
            new_freq = {}
            util = {}
            for c in clusters:
                util[c] = window_util(c, w0, next_window_end)
                new_freq[c] = governor.update(cl_type[c], freq[c], util[c])
            if throttle or recording:
                p = window_node_power(w0, next_window_end)
                temps = _thermal.exact_step(temps, p, *rc_ab)
            if throttle:
                for c in clusters:
                    cur = nearest_level(c, new_freq[c])
                    idx = throttle_index(
                        np.asarray([cur]),
                        np.asarray([temps[cl_node[c]]]), pol.thermal_cap_c)
                    new_freq[c] = cl_opps[c][int(idx[0])]
            freq.update(new_freq)
            if recording:
                telemetry.on_window(
                    next_window_end, util, dict(freq),
                    {c: nearest_level(c, freq[c]) for c in clusters},
                    p, temps)
            # records drained before this boundary can never overlap a later
            # window — prune so the scans stay O(in-flight), not O(history)
            committed[:] = [r for r in committed
                            if r.finish_us > next_window_end]
            next_window_end += window_us

    # per-job task state
    num_jobs = trace.num_jobs
    job_apps = [apps[int(a)] for a in trace.app_index]
    finish: Dict[Tuple[int, int], float] = {}
    on_pe: Dict[Tuple[int, int], int] = {}
    n_done_preds: Dict[Tuple[int, int], int] = {}

    # heap entries carry a per-task version stamp: fault rollback can leave
    # stale entries whose (ready, …) key no longer reflects the re-simulated
    # predecessor finishes — bumping the version at invalidation makes them
    # skip cleanly at pop (without faults each task is pushed exactly once,
    # so versioning never changes fault-free behaviour)
    heap: List[Tuple[float, int, int, int]] = []   # (ready, job, task, ver)
    entry_ver: Dict[Tuple[int, int], int] = {}

    def push_epoch(ready_us: float, jid2: int, tid2: int) -> None:
        ver = entry_ver.get((jid2, tid2), 0) + 1
        entry_ver[(jid2, tid2)] = ver
        heapq.heappush(heap, (ready_us, jid2, tid2, ver))

    for jid in range(num_jobs):
        app = job_apps[jid]
        for t in app.tasks:
            n_done_preds[(jid, t.task_id)] = 0
            if not t.predecessors:
                push_epoch(float(trace.arrival_us[jid]), jid, t.task_id)

    def apply_failure(pe_id: int, f_time: float) -> None:
        """Fail-stop ``pe_id`` at ``f_time``: roll back its unfinished tasks
        and (transitively) their committed descendants, re-enqueue them."""
        failed.add(pe_id)
        invalid = {(r.job_id, r.task_id) for r in records
                   if r.pe_id == pe_id and r.finish_us > f_time}
        changed = True
        while changed:          # descendants of invalidated tasks
            changed = False
            for r in records:
                key = (r.job_id, r.task_id)
                if key in invalid:
                    continue
                preds_r = job_apps[r.job_id].tasks[r.task_id].predecessors
                if any((r.job_id, p) in invalid for p in preds_r):
                    invalid.add(key)
                    changed = True
        if not invalid:
            return
        records[:] = [r for r in records if (r.job_id, r.task_id) not in invalid]
        committed[:] = [r for r in committed
                        if (r.job_id, r.task_id) not in invalid]
        for key in invalid:
            finish.pop(key, None)
            on_pe.pop(key, None)
        # recompute queue drain times from the surviving schedule
        pe_free[:] = 0.0
        for r in records:
            pe_free[r.pe_id] = max(pe_free[r.pe_id], r.finish_us)
        pe_free[pe_id] = np.float32(np.inf)
        # reset dependency counters so pred re-completion re-unlocks children
        # (also for PENDING tasks whose pred got invalidated: their heap
        # entries are version-stale — skipped at pop, re-pushed via unlock)
        for jid2 in range(num_jobs):
            for t2 in job_apps[jid2].tasks:
                key2 = (jid2, t2.task_id)
                if key2 in finish:
                    continue
                n_done_preds[key2] = sum(
                    1 for p in t2.predecessors if (jid2, p) in finish)
                if any((jid2, p) in invalid for p in t2.predecessors):
                    entry_ver[key2] = entry_ver.get(key2, 0) + 1
        # re-enqueue invalidated tasks whose preds are all still committed
        for jid2, tid2 in invalid:
            app2 = job_apps[jid2]
            preds2 = app2.tasks[tid2].predecessors
            if all((jid2, p) in finish for p in preds2):
                r2 = max([float(trace.arrival_us[jid2]), f_time]
                         + [finish[(jid2, p)] for p in preds2])
                push_epoch(r2, jid2, tid2)

    records: List[TaskRecord] = []
    while heap:
        ready, jid, tid, ver = heapq.heappop(heap)
        # trigger any fail-stop events that precede this epoch
        for pe_id, f_time in sorted(fail_at.items(), key=lambda kv: kv[1]):
            if pe_id not in failed and f_time <= ready:
                apply_failure(pe_id, f_time)
        app = job_apps[jid]
        task = app.tasks[tid]
        if ver != entry_ver.get((jid, tid)):
            continue                      # superseded by a rollback re-push
        if (jid, tid) in finish:          # re-queued duplicate after rollback
            continue
        if any((jid, p) not in finish for p in task.predecessors):
            continue                      # stale entry: pred was rolled back
        advance_windows(ready)
        fs = freq_scale_vec()

        preds = task.predecessors
        ctx = SchedContext(
            now_us=ready,
            pe_free_us=pe_free.copy(),
            app=app, task_id=tid, job_id=jid,
            pred_finish_us=np.array([finish[(jid, p)] for p in preds], dtype=np.float32),
            pred_pe=np.array([on_pe[(jid, p)] for p in preds], dtype=np.int32),
            pred_bytes=np.array([app.tasks[p].out_bytes for p in preds], dtype=np.float32),
            freq_scale=fs,
            available=np.array([j not in failed for j in range(n_pes)]),
        )
        pe_id = scheduler.pick_pe(db, ctx)
        pe = db.pes[pe_id]

        # data-ready time on the chosen PE (comm from producer PEs)
        data_ready = np.float32(ready)
        for k, p in enumerate(preds):
            src = db.pes[int(ctx.pred_pe[k])]
            comm = db.comm.latency(float(ctx.pred_bytes[k]), src, pe)
            data_ready = max(data_ready, np.float32(ctx.pred_finish_us[k] + np.float32(comm)))

        exec_us = db.latency(task.name, pe, float(fs[pe_id]))
        assert np.isfinite(exec_us), \
            f"scheduler chose unsupported PE {pe.name} for task {task.name}"
        start = max(np.float32(data_ready), pe_free[pe_id])
        fin = np.float32(start + np.float32(exec_us))
        pe_free[pe_id] = fin

        f_ghz = freq[pe.cluster] if pe.is_cpu else 0.0
        rec = TaskRecord(jid, tid, pe_id, float(ready), float(start), float(fin),
                         float(f_ghz))
        records.append(rec)
        committed.append(rec)
        finish[(jid, tid)] = float(fin)
        on_pe[(jid, tid)] = pe_id

        # unlock children
        for child in app.tasks:
            if tid in child.predecessors:
                key = (jid, child.task_id)
                n_done_preds[key] += 1
                if n_done_preds[key] == len(child.predecessors):
                    r = max(float(trace.arrival_us[jid]),
                            max(finish[(jid, p)] for p in child.predecessors))
                    push_epoch(r, jid, child.task_id)

    job_finish = np.zeros(num_jobs, dtype=np.float32)
    for r in records:
        job_finish[r.job_id] = max(job_finish[r.job_id], r.finish_us)
    makespan = float(max((r.finish_us for r in records), default=0.0))
    if recording:
        # drain the windows between the last decision epoch and the makespan
        # (mirroring the JAX kernel's post-scan drain): the timeline covers
        # the execution tail, including the final partial window
        while next_window_end - window_us < makespan:
            advance_windows(next_window_end)
    intervals = [(r.pe_id, r.start_us, r.finish_us,
                  r.freq_ghz if db.pes[r.pe_id].is_cpu else 0.0) for r in records]
    energy = energy_from_schedule(db, intervals, makespan)
    return SimResult(records, trace.arrival_us.copy(), job_finish, makespan, energy)
