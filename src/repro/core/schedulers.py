"""Pluggable schedulers: MET, ETF, table-based (ILP), + registry.

Semantics follow DS3: a task is *assigned* to a PE's FIFO queue at the moment
it becomes ready (its decision epoch); the PE then executes its queue in
order.  The scheduler's job is to pick the PE.

* **MET** (Braun et al. '01): pick the PE whose *execution time* for the task
  is minimal — a naive view of system state ("only considering PEs with best
  execution times"); ties broken by earliest-available PE of that type.
* **ETF** (Blythe et al. '05): pick the PE with earliest *finish* time,
  accounting for the PE's current queue backlog AND the communication cost of
  moving the task's inputs from the PEs that produced them.
* **TableScheduler**: replays any offline schedule (e.g. an ILP solution)
  from a (application, task_id) -> pe_id lookup table.

New schedulers plug in via ``@register_scheduler("name")``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .applications import Application
from .resources import PE, ResourceDB, INF

# --------------------------------------------------------------------------
# Scheduler interface + registry
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SchedContext:
    """Snapshot handed to the scheduler at a decision epoch."""
    now_us: float
    pe_free_us: np.ndarray            # (num_pes,) time each PE's queue drains
    # For the task being scheduled:
    app: Application
    task_id: int
    job_id: int
    pred_finish_us: np.ndarray        # (num_preds,) finish times of parents
    pred_pe: np.ndarray               # (num_preds,) PE ids of parents
    pred_bytes: np.ndarray            # (num_preds,) payload bytes
    freq_scale: np.ndarray            # (num_pes,) DVFS slowdown per PE
    available: Optional[np.ndarray] = None   # (num_pes,) False = failed PE


class Scheduler:
    name = "base"

    def pick_pe(self, db: ResourceDB, ctx: SchedContext) -> int:
        raise NotImplementedError

    def reset(self) -> None:  # called once per simulation
        pass


_REGISTRY: Dict[str, Callable[..., Scheduler]] = {}


def register_scheduler(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_scheduler(name: str, **kwargs) -> Scheduler:
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; have {sorted(_REGISTRY)}")


def available_schedulers() -> List[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Helpers
# --------------------------------------------------------------------------

def exec_times(db: ResourceDB, task_name: str, freq_scale: np.ndarray) -> np.ndarray:
    """(num_pes,) execution time of the task on each PE (INF = unsupported)."""
    out = np.full(db.num_pes, np.inf, dtype=np.float32)
    for j, pe in enumerate(db.pes):
        base = db.profiles.get(task_name, {}).get(pe.pe_type, INF)
        out[j] = base * (freq_scale[j] if pe.is_cpu else 1.0)
    return out


def ready_time_per_pe(db: ResourceDB, ctx: SchedContext) -> np.ndarray:
    """(num_pes,) earliest time the task's inputs can be present on each PE."""
    n = db.num_pes
    ready = np.full(n, ctx.now_us, dtype=np.float32)
    for k in range(len(ctx.pred_finish_us)):
        src = db.pes[int(ctx.pred_pe[k])]
        for j, pe in enumerate(db.pes):
            comm = db.comm.latency(float(ctx.pred_bytes[k]), src, pe)
            ready[j] = max(ready[j], float(ctx.pred_finish_us[k]) + comm)
    return ready


# --------------------------------------------------------------------------
# Built-in schedulers
# --------------------------------------------------------------------------

@register_scheduler("met")
class METScheduler(Scheduler):
    """Minimum Execution Time — naive: ignores queue state and comm cost.

    Canonical MET (Braun et al. '01): assign to the PE with minimum execution
    time *regardless of availability*; ties resolve to the first such PE, so
    load concentrates — exactly the paper's "naive representation of the
    system state" failure mode at high injection rates.
    """

    def pick_pe(self, db: ResourceDB, ctx: SchedContext) -> int:
        ex = exec_times(db, ctx.app.tasks[ctx.task_id].name, ctx.freq_scale)
        if ctx.available is not None:
            ex = np.where(ctx.available, ex, np.inf)
        return int(np.argmin(ex))


@register_scheduler("etf")
class ETFScheduler(Scheduler):
    """Earliest Task Finish — uses comm cost + live PE queue state."""

    def pick_pe(self, db: ResourceDB, ctx: SchedContext) -> int:
        ex = exec_times(db, ctx.app.tasks[ctx.task_id].name, ctx.freq_scale)
        ready = ready_time_per_pe(db, ctx)
        start = np.maximum(ready, ctx.pe_free_us.astype(np.float32))
        finish = start + ex
        if ctx.available is not None:
            finish = np.where(ctx.available, finish, np.inf)
        return int(np.argmin(finish))


@register_scheduler("table")
class TableScheduler(Scheduler):
    """Replay an offline (ILP) schedule: (app_name, task_id) -> pe id.

    When an application has several instances in flight the table maps each
    task to the *type-level* assignment computed for one job instance; among
    the PEs of that id's type we take the given id directly (static table, as
    in the paper: "optimal for one job instance").
    """

    def __init__(self, table: Mapping[Tuple[str, int], int]):
        self.table = dict(table)

    def pick_pe(self, db: ResourceDB, ctx: SchedContext) -> int:
        return int(self.table[(ctx.app.name, ctx.task_id)])


# --------------------------------------------------------------------------
# Offline ILP-style optimiser (exact, small DAGs): builds TableScheduler input
# --------------------------------------------------------------------------

def solve_optimal_table(db: ResourceDB, app: Application,
                        max_states: int = 2_000_000) -> Dict[Tuple[str, int], int]:
    """Exact minimum-makespan PE assignment for ONE job instance.

    Exhaustive branch-and-bound over task->PE assignments in topological
    order (the reference DAGs have ≤ 10 tasks, and identical PEs are
    symmetry-broken), mirroring the ILP table of the paper.

    Secondary objective (lexicographic): among equal-makespan optima,
    minimise the maximum per-PE busy time — an ILP solver free to pick any
    optimum would emit *some* spread assignment; taking the max-load-minimal
    one makes the table behave like a static pipeline when jobs interleave,
    which is the regime of paper Fig. 3.
    """
    T = app.num_tasks
    n = db.num_pes
    ex = db.latency_matrix(app.task_names)           # (T, n)
    preds = [t.predecessors for t in app.tasks]
    ebytes = app.edge_bytes_matrix()

    best = {"key": (np.inf, np.inf), "assign": None}

    pe_list = db.pes

    def comm(pbytes: float, src: int, dst: int) -> float:
        return db.comm.latency(pbytes, pe_list[src], pe_list[dst])

    def rec(i: int, assign: List[int], finish: List[float], pe_free: List[float],
            pe_load: List[float], states: List[int]):
        states[0] += 1
        if states[0] > max_states:
            return
        cur = (max(finish) if finish else 0.0, max(pe_load) if assign else 0.0)
        if cur >= best["key"]:
            return
        if i == T:
            best["key"] = cur
            best["assign"] = list(assign)
            return
        # symmetry breaking: among identical-state PEs of a type keep first
        seen_types = set()
        order = np.argsort(ex[i])
        for j in order:
            j = int(j)
            if not np.isfinite(ex[i, j]):
                continue
            key = (pe_list[j].pe_type, pe_free[j], pe_load[j])
            if key in seen_types:
                continue
            seen_types.add(key)
            ready = 0.0
            for p in preds[i]:
                ready = max(ready, finish[p] + comm(float(ebytes[i, p]), assign[p], j))
            start = max(ready, pe_free[j])
            f = start + float(ex[i, j])
            old_free, old_load = pe_free[j], pe_load[j]
            assign.append(j); finish.append(f)
            pe_free[j] = f; pe_load[j] = old_load + float(ex[i, j])
            rec(i + 1, assign, finish, pe_free, pe_load, states)
            assign.pop(); finish.pop(); pe_free[j] = old_free; pe_load[j] = old_load

    rec(0, [], [], [0.0] * n, [0.0] * n, [0])
    assert best["assign"] is not None, "optimal table search failed"
    return {(app.name, t): int(best["assign"][t]) for t in range(T)}
