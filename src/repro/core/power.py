"""Analytical power & energy models (paper §2, after Bhat et al. TVLSI'18).

Dynamic power of a CPU PE at operating point (f GHz, V volt):
    P_dyn = Ceff · V² · f          [W]   (Ceff in nF ⇒ numbers land in watts)
Static leakage is a per-type constant.  Accelerators have a fixed active
power.  Energy = Σ P·Δt over busy/idle intervals.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .resources import (ACC_POWER_ACTIVE, NOMINAL_FREQ, OPP_TABLE, PE,
                        POWER_COEFF, ResourceDB)


def opp_voltage(pe_type: str, freq_ghz: float) -> float:
    """Voltage at the smallest OPP with f >= freq (linear clamp at ends)."""
    table = OPP_TABLE[pe_type]
    freqs = [f for f, _ in table]
    i = bisect.bisect_left(freqs, freq_ghz - 1e-9)
    i = min(i, len(table) - 1)
    return table[i][1]


def active_power(pe: PE, freq_ghz: float) -> float:
    """Active power draw (W) of a PE executing a task."""
    if pe.is_cpu:
        v = opp_voltage(pe.pe_type, freq_ghz)
        c = POWER_COEFF[pe.pe_type]
        return c["ceff"] * v * v * freq_ghz + c["leak"]
    return ACC_POWER_ACTIVE[pe.pe_type] + POWER_COEFF[pe.pe_type]["leak"]


def idle_power(pe: PE) -> float:
    return POWER_COEFF[pe.pe_type]["leak"]


@dataclasses.dataclass
class EnergyReport:
    total_energy_j: float
    energy_per_pe_j: np.ndarray           # (num_pes,)
    busy_per_pe_us: np.ndarray            # (num_pes,)
    avg_power_w: float
    makespan_us: float


def energy_from_schedule(db: ResourceDB,
                         intervals: Sequence[Tuple[int, float, float, float]],
                         makespan_us: float) -> EnergyReport:
    """Integrate energy over a realised schedule.

    ``intervals``: (pe_id, start_us, finish_us, freq_ghz) per executed task.
    Idle time at leakage power fills the rest of the makespan.
    """
    n = db.num_pes
    busy = np.zeros(n, dtype=np.float64)
    e = np.zeros(n, dtype=np.float64)
    for pe_id, s, f, freq in intervals:
        pe = db.pes[pe_id]
        dt = max(0.0, f - s)
        busy[pe_id] += dt
        e[pe_id] += active_power(pe, freq) * dt          # W·us = uJ
    for j, pe in enumerate(db.pes):
        idle = max(0.0, makespan_us - busy[j])
        e[j] += idle_power(pe) * idle
    total_j = float(e.sum()) * 1e-6                      # uJ -> J
    avg_p = float(e.sum()) * 1e-6 / max(makespan_us * 1e-6, 1e-12)
    return EnergyReport(total_j, e * 1e-6, busy, avg_p, makespan_us)
