"""Reporting: schedule tables + performance/throughput/energy summaries.

The paper: "the framework generates plots and reports of schedule,
performance, throughput, and energy consumption".  Headless environment ⇒
ASCII Gantt + CSV emitters (matplotlib optional, not required).
"""
from __future__ import annotations

import io
from typing import List, Optional, Sequence

import numpy as np

from .resources import ResourceDB
from .simkernel_ref import SimResult


def schedule_table(db: ResourceDB, result: SimResult, max_rows: int = 40) -> str:
    out = io.StringIO()
    out.write(f"{'job':>4} {'task':>4} {'pe':>8} {'ready':>10} {'start':>10} "
              f"{'finish':>10} {'f(GHz)':>7}\n")
    for r in result.records[:max_rows]:
        out.write(f"{r.job_id:>4} {r.task_id:>4} {db.pes[r.pe_id].name:>8} "
                  f"{r.ready_us:>10.2f} {r.start_us:>10.2f} {r.finish_us:>10.2f} "
                  f"{r.freq_ghz:>7.2f}\n")
    if len(result.records) > max_rows:
        out.write(f"... ({len(result.records) - max_rows} more rows)\n")
    return out.getvalue()


def gantt_ascii(db: ResourceDB, result: SimResult, width: int = 100,
                t_end_us: Optional[float] = None) -> str:
    """ASCII Gantt chart of the realised schedule (one row per PE)."""
    t_end = t_end_us or result.makespan_us
    if t_end <= 0:
        return "(empty schedule)\n"
    scale = width / t_end
    rows = {pe.pe_id: [" "] * width for pe in db.pes}
    for r in result.records:
        a = int(r.start_us * scale)
        b = max(a + 1, int(r.finish_us * scale))
        ch = str(r.job_id % 10)
        for k in range(a, min(b, width)):
            rows[r.pe_id][k] = ch
    out = io.StringIO()
    for pe in db.pes:
        out.write(f"{pe.name:>8} |{''.join(rows[pe.pe_id])}|\n")
    out.write(f"{'':>8}  0{'':{width - 12}}{t_end:.0f} us\n")
    return out.getvalue()


def summary_csv(rows: Sequence[dict]) -> str:
    """Rows of {scheduler, rate, avg_latency_us, throughput, energy_j} -> CSV."""
    if not rows:
        return ""
    keys = list(rows[0].keys())
    out = io.StringIO()
    out.write(",".join(keys) + "\n")
    for r in rows:
        out.write(",".join(f"{r[k]:.4f}" if isinstance(r[k], float) else str(r[k])
                           for k in keys) + "\n")
    return out.getvalue()


def summarize(db: ResourceDB, result: SimResult, scheduler: str, rate: float) -> dict:
    return dict(
        scheduler=scheduler,
        rate_jobs_per_ms=float(rate),
        num_jobs=len(result.job_finish_us),
        avg_job_latency_us=result.avg_job_latency_us,
        throughput_jobs_per_ms=result.throughput_jobs_per_ms,
        makespan_us=result.makespan_us,
        energy_j=result.energy.total_energy_j,
        avg_power_w=result.energy.avg_power_w,
    )
