"""Lumped RC thermal model (paper §2 — temperature exploration for DTPM).

A small thermal network: one node per cluster (big, LITTLE, accelerator
fabric) plus a board node coupled to ambient.  Forward-Euler integration:

    C_i · dT_i/dt = P_i − (T_i − T_board)/R_i
    C_b · dT_b/dt = Σ_i (T_i − T_board)/R_i − (T_b − T_amb)/R_b

Constants are in the calibrated range for an Odroid-XU3 class board.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

T_AMBIENT_C = 25.0

# node order: [big cluster, LITTLE cluster, accel fabric, board]
NODE_BIG, NODE_LITTLE, NODE_ACCEL = 0, 1, 2
NUM_NODES = 3
R_TO_BOARD = np.array([2.0, 4.0, 3.0], dtype=np.float64)     # K/W
C_NODE = np.array([0.15, 0.05, 0.10], dtype=np.float64)      # J/K
R_BOARD_AMB = 1.5                                            # K/W
C_BOARD = 20.0                                               # J/K


def cluster_nodes(db) -> np.ndarray:
    """Map each PE of a ``ResourceDB`` to its thermal node index.

    big CPUs -> NODE_BIG, LITTLE CPUs -> NODE_LITTLE, accelerators share the
    NODE_ACCEL fabric node.
    """
    from .resources import CPU_BIG, CPU_LITTLE
    out = np.empty(db.num_pes, dtype=np.int64)
    for j, pe in enumerate(db.pes):
        if pe.pe_type == CPU_BIG:
            out[j] = NODE_BIG
        elif pe.pe_type == CPU_LITTLE:
            out[j] = NODE_LITTLE
        else:
            out[j] = NODE_ACCEL
    return out


def node_power_split(db, energy_per_pe_j: np.ndarray,
                     makespan_us: float) -> np.ndarray:
    """Average per-thermal-node power (W) realised by a schedule.

    Replaces any fixed big/LITTLE/accel split assumption: the split is derived
    from the energy each PE actually consumed over the makespan.
    """
    # EnergyReport.energy_per_pe_j stores W·us · 1e-6 = joules — the same
    # convention its avg_power_w is derived with.
    per_pe_w = (np.asarray(energy_per_pe_j, dtype=np.float64)
                / max(float(makespan_us) * 1e-6, 1e-12))
    return np.bincount(cluster_nodes(db), weights=per_pe_w,
                       minlength=NUM_NODES)[:NUM_NODES]


@dataclasses.dataclass
class ThermalState:
    t_node_c: np.ndarray     # (3,) cluster temperatures
    t_board_c: float

    @classmethod
    def ambient(cls) -> "ThermalState":
        return cls(np.full(3, T_AMBIENT_C), T_AMBIENT_C)


def rc_state_matrix() -> np.ndarray:
    """(4, 4) continuous-time state matrix M of the linear RC network.

    dx/dt = M x + u with x = [T_big, T_little, T_accel, T_board] and
    u = [P/C_node..., T_amb/(R_b·C_b)].  Shared by the numpy reference, the
    ``dse.thermal_jax`` batched pipeline and the DTPM simulation kernels —
    one definition, three integrators.
    """
    a = 1.0 / (R_TO_BOARD * C_NODE)                               # (3,)
    top = np.concatenate([np.diag(-a), a[:, None]], axis=1)       # (3, 4)
    b_in = 1.0 / (R_TO_BOARD * C_BOARD)                           # (3,)
    b_out = -(np.sum(1.0 / R_TO_BOARD) + 1.0 / R_BOARD_AMB) / C_BOARD
    bottom = np.concatenate([b_in, [b_out]])[None]                # (1, 4)
    return np.concatenate([top, bottom], axis=0)


def exact_step_matrices(dt_s: float) -> Tuple[np.ndarray, np.ndarray]:
    """(A, B) of the exact piecewise-constant update x' = A x + B u.

    A = e^{M·dt}, B = M⁻¹(e^{M·dt} − I): unconditionally stable for any step
    width (DESIGN.md §6) — this is the per-window update the DTPM governors'
    thermal-throttle feedback integrates inside both simulation kernels.
    """
    import scipy.linalg
    M = rc_state_matrix()
    A = scipy.linalg.expm(M * float(dt_s))
    B = np.linalg.solve(M, A - np.eye(4))
    return A, B


def exact_step(temps: np.ndarray, power_w: np.ndarray,
               A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Advance the (4,) [nodes..., board] state one window under (3,) power."""
    u = np.concatenate([np.asarray(power_w, np.float64) / C_NODE,
                        [T_AMBIENT_C / (R_BOARD_AMB * C_BOARD)]])
    return A @ np.asarray(temps, np.float64) + B @ u


_RC_SPECTRAL = None


def _rc_spectral():
    """Host-precomputed (float64) spectral decomposition of the constant RC
    state matrix: eigenvalues λ_j and rank-1 projectors P_j = v_j ⊗ w_j with
    M = Σ λ_j P_j.  The RC network is similar to a symmetric matrix (via the
    diagonal capacitance scaling), so the spectrum is real — asserted here.
    """
    global _RC_SPECTRAL  # lint: waive JX003 -- host-side memo of constant spectral data; idempotent, populated on first trace
    if _RC_SPECTRAL is None:
        lam, V = np.linalg.eig(rc_state_matrix())
        assert np.abs(lam.imag).max() == 0.0, "RC spectrum must be real"
        proj = np.einsum("ij,jk->jik", V.real, np.linalg.inv(V).real)  # (4,4,4)
        _RC_SPECTRAL = (lam.real, proj)
    return _RC_SPECTRAL


def exact_step_matrices_jax(dt_s):
    """Traceable (jnp) twin of :func:`exact_step_matrices` — the single
    definition the DTPM kernel and ``dse.thermal_jax`` consume.

    Computed spectrally — A = Σ e^{λ_j·dt} P_j, B = Σ (e^{λ_j·dt}−1)/λ_j P_j
    with host-precomputed constant λ/P — instead of a traced ``expm``:
    elementwise exps plus a fixed-order unrolled sum have lane-batch-width
    independent rounding, so vmapped thermal lanes are bit-for-bit stable
    under the sharded/chunked sweep executor (DESIGN.md §13), where XLA's
    batched-``expm`` linalg was not.
    """
    import jax.numpy as jnp
    lam, proj = _rc_spectral()
    dt = jnp.asarray(dt_s, jnp.float32)
    A = B = None
    for j in range(len(lam)):
        lam_j = jnp.float32(lam[j])
        p_j = jnp.asarray(proj[j], jnp.float32)
        e_j = jnp.exp(lam_j * dt)
        a_t = e_j[..., None, None] * p_j
        b_t = ((e_j - 1.0) / lam_j)[..., None, None] * p_j
        A = a_t if A is None else A + a_t
        B = b_t if B is None else B + b_t
    return A, B


def exact_step_jax(temps, power_w, A, B):
    """Traceable twin of :func:`exact_step` on (4,) temps / (3,) node power."""
    import jax.numpy as jnp
    u = jnp.concatenate([
        jnp.asarray(power_w, jnp.float32) / jnp.asarray(C_NODE, jnp.float32),
        jnp.full((1,), T_AMBIENT_C / (R_BOARD_AMB * C_BOARD), jnp.float32)])
    return A @ temps + B @ u


def step(state: ThermalState, power_w: np.ndarray, dt_s: float) -> ThermalState:
    """One forward-Euler step.  ``power_w``: (3,) per-cluster power."""
    flow = (state.t_node_c - state.t_board_c) / R_TO_BOARD
    t_node = state.t_node_c + dt_s / C_NODE * (power_w - flow)
    t_board = state.t_board_c + dt_s / C_BOARD * (
        flow.sum() - (state.t_board_c - T_AMBIENT_C) / R_BOARD_AMB)
    return ThermalState(t_node, float(t_board))


def simulate_trace(power_trace_w: np.ndarray, dt_s: float,
                   init: ThermalState | None = None) -> np.ndarray:
    """Integrate a (steps × 3) cluster power trace; returns (steps × 4) temps."""
    st = init or ThermalState.ambient()
    out = np.zeros((power_trace_w.shape[0], 4), dtype=np.float64)
    for i in range(power_trace_w.shape[0]):
        st = step(st, power_trace_w[i], dt_s)
        out[i, :3] = st.t_node_c
        out[i, 3] = st.t_board_c
    return out


def steady_state(power_w: np.ndarray) -> np.ndarray:
    """Analytical steady-state temps for constant cluster power (sanity oracle)."""
    tb = T_AMBIENT_C + R_BOARD_AMB * float(power_w.sum())
    return np.concatenate([tb + R_TO_BOARD * power_w, [tb]])
