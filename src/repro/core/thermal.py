"""Lumped RC thermal model (paper §2 — temperature exploration for DTPM).

A small thermal network: one node per cluster (big, LITTLE, accelerator
fabric) plus a board node coupled to ambient.  Forward-Euler integration:

    C_i · dT_i/dt = P_i − (T_i − T_board)/R_i
    C_b · dT_b/dt = Σ_i (T_i − T_board)/R_i − (T_b − T_amb)/R_b

Constants are in the calibrated range for an Odroid-XU3 class board.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

T_AMBIENT_C = 25.0

# node order: [big cluster, LITTLE cluster, accel fabric, board]
R_TO_BOARD = np.array([2.0, 4.0, 3.0], dtype=np.float64)     # K/W
C_NODE = np.array([0.15, 0.05, 0.10], dtype=np.float64)      # J/K
R_BOARD_AMB = 1.5                                            # K/W
C_BOARD = 20.0                                               # J/K


@dataclasses.dataclass
class ThermalState:
    t_node_c: np.ndarray     # (3,) cluster temperatures
    t_board_c: float

    @classmethod
    def ambient(cls) -> "ThermalState":
        return cls(np.full(3, T_AMBIENT_C), T_AMBIENT_C)


def step(state: ThermalState, power_w: np.ndarray, dt_s: float) -> ThermalState:
    """One forward-Euler step.  ``power_w``: (3,) per-cluster power."""
    flow = (state.t_node_c - state.t_board_c) / R_TO_BOARD
    t_node = state.t_node_c + dt_s / C_NODE * (power_w - flow)
    t_board = state.t_board_c + dt_s / C_BOARD * (
        flow.sum() - (state.t_board_c - T_AMBIENT_C) / R_BOARD_AMB)
    return ThermalState(t_node, float(t_board))


def simulate_trace(power_trace_w: np.ndarray, dt_s: float,
                   init: ThermalState | None = None) -> np.ndarray:
    """Integrate a (steps × 3) cluster power trace; returns (steps × 4) temps."""
    st = init or ThermalState.ambient()
    out = np.zeros((power_trace_w.shape[0], 4), dtype=np.float64)
    for i in range(power_trace_w.shape[0]):
        st = step(st, power_trace_w[i], dt_s)
        out[i, :3] = st.t_node_c
        out[i, 3] = st.t_board_c
    return out


def steady_state(power_w: np.ndarray) -> np.ndarray:
    """Analytical steady-state temps for constant cluster power (sanity oracle)."""
    tb = T_AMBIENT_C + R_BOARD_AMB * float(power_w.sum())
    return np.concatenate([tb + R_TO_BOARD * power_w, [tb]])
