"""DAG application models — the five reference applications of the paper.

An application is a directed acyclic graph of named tasks (paper Fig. 2 shows
WiFi-TX).  Each edge carries a payload size (bytes) used by the analytical
interconnect model.  Task latencies live in the resource database
(``resources.ALL_PROFILES``), keyed by task name.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Task:
    name: str
    task_id: int                       # index within the application DAG
    predecessors: Tuple[int, ...]      # task_ids of parents
    out_bytes: float = 1024.0          # payload produced for each successor


@dataclasses.dataclass(frozen=True)
class Application:
    """A DAG application (one *job* = one instance of an application)."""
    name: str
    tasks: Tuple[Task, ...]

    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    @property
    def task_names(self) -> List[str]:
        return [t.name for t in self.tasks]

    def pred_matrix(self) -> np.ndarray:
        """(T × T) bool: pred_matrix[i, j] = task j is a predecessor of i."""
        m = np.zeros((self.num_tasks, self.num_tasks), dtype=bool)
        for t in self.tasks:
            for p in t.predecessors:
                m[t.task_id, p] = True
        return m

    def edge_bytes_matrix(self) -> np.ndarray:
        """(T × T) float: bytes flowing j -> i (0 when no edge)."""
        m = np.zeros((self.num_tasks, self.num_tasks), dtype=np.float32)
        for t in self.tasks:
            for p in t.predecessors:
                m[t.task_id, p] = self.tasks[p].out_bytes
        return m

    def validate(self) -> None:
        for t in self.tasks:
            assert all(p < t.task_id for p in t.predecessors), \
                f"{self.name}: tasks must be topologically ordered"


def _chain(name: str, task_names: Sequence[str], out_bytes: float = 1024.0) -> Application:
    tasks = tuple(
        Task(n, i, (i - 1,) if i > 0 else (), out_bytes)
        for i, n in enumerate(task_names)
    )
    app = Application(name, tasks)
    app.validate()
    return app


# --------------------------------------------------------------------------
# The five reference applications (wireless communication + radar domains)
# --------------------------------------------------------------------------

def wifi_tx() -> Application:
    """Paper Fig. 2: WiFi transmitter pipeline."""
    return _chain("wifi_tx", [
        "scrambler_encoder", "interleaver", "qpsk_modulation",
        "pilot_insertion", "inverse_fft", "crc",
    ])


def wifi_rx() -> Application:
    """WiFi receiver: two front-end branches joining at the demodulator."""
    t = [
        Task("match_filter",      0, (), 2048),
        Task("payload_extract",   1, (0,), 2048),
        Task("fft",               2, (1,), 2048),
        Task("pilot_extract",     3, (2,), 512),
        Task("qpsk_demodulation", 4, (2, 3), 1024),
        Task("deinterleaver",     5, (4,), 1024),
        Task("viterbi_decoder",   6, (5,), 1024),
    ]
    app = Application("wifi_rx", tuple(t))
    app.validate()
    return app


def single_carrier() -> Application:
    """Low-power single-carrier TX/RX loop."""
    t = [
        Task("scrambler_encoder", 0, (), 512),
        Task("sc_modulation",     1, (0,), 512),
        Task("rrc_filter",        2, (1,), 1024),
        Task("sync",              3, (2,), 1024),
        Task("sc_demodulation",   4, (3,), 512),
        Task("crc",               5, (4,), 256),
    ]
    app = Application("single_carrier", tuple(t))
    app.validate()
    return app


def range_detection() -> Application:
    """Radar range detection: parallel FFT of reference & received chirps."""
    t = [
        Task("lfm_gen",       0, (), 4096),
        Task("fft",           1, (0,), 4096),    # FFT(reference)
        Task("fft",           2, (0,), 4096),    # FFT(received)
        Task("conj_multiply", 3, (1, 2), 4096),
        Task("inverse_fft",   4, (3,), 4096),
        Task("amplitude",     5, (4,), 2048),
        Task("peak_detect",   6, (5,), 64),
    ]
    app = Application("range_detection", tuple(t))
    app.validate()
    return app


def pulse_doppler() -> Application:
    """Pulse-Doppler radar: a bank of parallel FFTs, then Doppler processing."""
    nfft = 4
    tasks: List[Task] = [Task("pd_stack", 0, (), 4096)]
    for i in range(nfft):
        tasks.append(Task("fft", 1 + i, (0,), 4096))
    join = 1 + nfft
    tasks.append(Task("doppler_fft", join, tuple(range(1, 1 + nfft)), 4096))
    tasks.append(Task("amplitude", join + 1, (join,), 2048))
    tasks.append(Task("cfar", join + 2, (join + 1,), 1024))
    app = Application("pulse_doppler", tuple(tasks))
    app.validate()
    return app


REFERENCE_APPS = {
    "wifi_tx": wifi_tx,
    "wifi_rx": wifi_rx,
    "single_carrier": single_carrier,
    "range_detection": range_detection,
    "pulse_doppler": pulse_doppler,
}


def get_application(name: str) -> Application:
    try:
        return REFERENCE_APPS[name]()
    except KeyError:
        raise KeyError(f"unknown application {name!r}; have {sorted(REFERENCE_APPS)}")
