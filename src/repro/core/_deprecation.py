"""Shared shim factory for the one-release deprecation policy (DESIGN.md §4,
§9): legacy entry points warn and delegate to the unchanged internals."""
from __future__ import annotations

import functools
import warnings


def deprecated_entry_point(fn, alternative: str):
    """Warn-and-delegate wrapper around an unchanged internal entry point."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"calling {fn.__name__} directly is deprecated; use {alternative}",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)
    return wrapper
