"""Shared shim factory for the one-release deprecation policy (DESIGN.md §4,
§9): legacy entry points warn and delegate to the unchanged internals."""
from __future__ import annotations

import functools
import warnings


def deprecated_entry_point(fn, alternative: str, energy_alias: bool = False):
    """Warn-and-delegate wrapper; ``energy_alias`` re-injects the one-release
    ``energy_mj`` output key (the value always was joules)."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"calling {fn.__name__} directly is deprecated; use {alternative}",
            DeprecationWarning, stacklevel=2)
        out = fn(*args, **kwargs)
        if energy_alias:
            out["energy_mj"] = out["energy_j"]
        return out
    return wrapper
