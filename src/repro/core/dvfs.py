"""DVFS governors (paper §2: "built-in DVFS governors deployed on commercial
SoCs") — performance, powersave, userspace, ondemand.

A governor controls the frequency of each CPU *cluster* (accelerators run at
fixed clocks).  ``ondemand`` mirrors the Linux governor: sample utilisation
over a window; if it exceeds ``up_threshold`` jump to f_max, otherwise step
down proportionally.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from .resources import CPU_BIG, CPU_LITTLE, NOMINAL_FREQ, OPP_TABLE, ResourceDB


class Governor:
    name = "base"

    def initial_freq(self, pe_type: str) -> float:
        raise NotImplementedError

    def update(self, pe_type: str, cur_freq: float, utilization: float) -> float:
        """Return the new cluster frequency given window utilisation in [0,1]."""
        return cur_freq


class PerformanceGovernor(Governor):
    name = "performance"

    def initial_freq(self, pe_type: str) -> float:
        return OPP_TABLE[pe_type][-1][0]


class PowersaveGovernor(Governor):
    name = "powersave"

    def initial_freq(self, pe_type: str) -> float:
        return OPP_TABLE[pe_type][0][0]


class UserspaceGovernor(Governor):
    name = "userspace"

    def __init__(self, freq_ghz: Dict[str, float] | float = 1.0):
        self._freq = freq_ghz

    def initial_freq(self, pe_type: str) -> float:
        if isinstance(self._freq, dict):
            return self._freq[pe_type]
        return float(self._freq)


class OndemandGovernor(Governor):
    """Linux-style ondemand: sampling window + up-threshold."""
    name = "ondemand"

    def __init__(self, up_threshold: float = 0.80, sample_window_us: float = 50.0):
        self.up_threshold = up_threshold
        self.sample_window_us = sample_window_us

    def initial_freq(self, pe_type: str) -> float:
        return OPP_TABLE[pe_type][0][0]

    def update(self, pe_type: str, cur_freq: float, utilization: float) -> float:
        opps = [f for f, _ in OPP_TABLE[pe_type]]
        if utilization > self.up_threshold:
            return opps[-1]
        # proportional step-down: target = fmax * util / up_threshold
        target = opps[-1] * max(utilization, 0.0) / self.up_threshold
        for f in opps:
            if f >= target - 1e-9:
                return f
        return opps[-1]


GOVERNORS = {
    "performance": PerformanceGovernor,
    "powersave": PowersaveGovernor,
    "userspace": UserspaceGovernor,
    "ondemand": OndemandGovernor,
}


def get_governor(name: str, **kw) -> Governor:
    try:
        return GOVERNORS[name](**kw)
    except KeyError:
        raise KeyError(f"unknown governor {name!r}; have {sorted(GOVERNORS)}")
