"""DVFS governors (paper §2: "built-in DVFS governors deployed on commercial
SoCs") — performance, powersave, userspace, ondemand, thermal throttle.

A governor controls the frequency of each CPU *cluster* (accelerators run at
fixed clocks).  ``ondemand`` mirrors the Linux governor: sample utilisation
over a window; if it exceeds ``up_threshold`` jump to f_max, otherwise step
down proportionally.  ``throttle`` is ondemand plus a thermal cap: when the
cluster's RC-model temperature exceeds the cap the cluster is clamped to its
lowest OPP for the next window.

**One policy, two kernels.**  The per-window transition is expressed once, in
array form, by :class:`GovernorPolicy` plus the pure step functions
:func:`ondemand_index` / :func:`throttle_index`.  The object-style governors
below are thin wrappers over those functions (``OndemandGovernor.update``
calls ``ondemand_index``), and the vectorised JAX kernel traces the *same*
functions with ``jnp`` inputs — ref↔jax governor semantics agree by
construction, not by parallel maintenance (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence

import jax
import numpy as np

from .resources import CPU_BIG, CPU_LITTLE, NOMINAL_FREQ, OPP_TABLE, ResourceDB

# Maximum OPP levels across CPU types — the K axis of every OPP-indexed table.
MAX_OPP_LEVELS = max(len(v) for v in OPP_TABLE.values())


def capped_levels(pe_type: str,
                  freq_caps: Optional[Mapping[str, float]]) -> List[float]:
    """The OPP ladder of ``pe_type`` truncated at a frequency cap.

    Design points carry per-cluster frequency caps; a dynamic governor's
    ladder stops at the cap (never below one level).  One definition feeds
    both the reference governor transition and ``build_tables``'s OPP-indexed
    ladders, so the two kernels agree on the capped OPP set by construction.
    """
    opps = [f for f, _ in OPP_TABLE[pe_type]]
    if freq_caps is not None and pe_type in freq_caps:
        capped = [f for f in opps if f <= freq_caps[pe_type] + 1e-9]
        opps = capped or opps[:1]
    return opps


def padded_ladder(pe_type: str,
                  freq_caps: Optional[Mapping[str, float]] = None):
    """``(levels, padded_row, count)`` for a capped ladder: ``padded_row``
    has ``MAX_OPP_LEVELS`` entries, ascending, top-padded by repeating the
    highest real level.  This padding convention is load-bearing for
    :func:`ondemand_index`'s first-covering argmax — every OPP table in the
    system (object governors, ``build_tables`` ladders, tests) must build
    through here.
    """
    opps = capped_levels(pe_type, freq_caps)
    row = opps + [opps[-1]] * (MAX_OPP_LEVELS - len(opps))
    return opps, row, len(opps)


# --------------------------------------------------------------------------
# Array-form policy — the representation both kernels execute
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GovernorPolicy:
    """Array-form DVFS policy: the per-window transition both kernels run.

    ``dynamic=False`` marks a static governor (performance / powersave /
    userspace): one OPP per cluster, fixed at table-build time — the JAX
    kernel compiles the whole window machinery away.  ``dynamic=True`` is the
    ondemand family: every ``sample_window_us`` of simulated time each
    cluster's utilisation drives :func:`ondemand_index`, the window's
    realised power advances the §6 RC network by the exact update over
    ``thermal_dt_s`` seconds, and clusters hotter than ``thermal_cap_c`` are
    clamped to their lowest OPP (:func:`throttle_index`).

    ``thermal_dt_s`` decouples thermal from schedule time: each sampling
    window's power is held for ``thermal_dt_s`` of wall-clock, treating the
    window as representative of a sustained streaming workload (the same
    assumption as DESIGN.md §6's periodic steady state) — so second-scale
    thermal responses are explorable from millisecond traces.  The dataclass
    default is 50 µs (the default window); :class:`OndemandGovernor` ties it
    to its actual ``sample_window_us`` (real-time integration) unless
    overridden, so construct policies through a governor when in doubt.

    Registered as a pytree whose *parameters are leaves* and whose shape flag
    is static: policies differing only in parameters batch under ``vmap``
    into ONE compiled program per policy shape.
    """
    dynamic: bool = False
    up_threshold: float = 0.80
    sample_window_us: float = 50.0
    thermal_cap_c: float = math.inf
    thermal_dt_s: float = 50.0e-6


jax.tree_util.register_dataclass(
    GovernorPolicy,
    data_fields=["up_threshold", "sample_window_us", "thermal_cap_c",
                 "thermal_dt_s"],
    meta_fields=["dynamic"])


def stack_policies(policies: Sequence[GovernorPolicy]) -> GovernorPolicy:
    """Stack G same-shape dynamic policies into one (G,)-leaf policy pytree
    ready for ``vmap`` (the sweep's policy-lane axis)."""
    if not policies:
        raise ValueError("empty policy list")
    if not all(p.dynamic for p in policies):
        raise ValueError("only dynamic policies batch; static governors are "
                         "compiled into the tables (DESIGN.md §7)")
    validate_policy_params([p.sample_window_us for p in policies],
                           [p.up_threshold for p in policies],
                           [p.thermal_dt_s for p in policies])
    import jax.numpy as jnp
    return GovernorPolicy(
        dynamic=True,
        up_threshold=jnp.asarray([p.up_threshold for p in policies],
                                 jnp.float32),
        sample_window_us=jnp.asarray([p.sample_window_us for p in policies],
                                     jnp.float32),
        thermal_cap_c=jnp.asarray([p.thermal_cap_c for p in policies],
                                  jnp.float32),
        thermal_dt_s=jnp.asarray([p.thermal_dt_s for p in policies],
                                 jnp.float32))


def validate_policy_params(sample_window_us, up_threshold, thermal_dt_s):
    """Positivity checks every dynamic-policy entry point shares (governor
    constructor, ``stack_policies``, ``simulate_jax_dtpm``).  Accepts scalars
    or arrays (stacked policy lanes)."""
    if not np.all(np.asarray(sample_window_us) > 0):
        raise ValueError("sample_window_us must be positive (a non-advancing "
                         "window would hang the kernel's window loop)")
    if not np.all(np.asarray(up_threshold) > 0):
        raise ValueError("up_threshold must be positive (zero would silently "
                         "pin clusters to fmin/fmax)")
    if not np.all(np.asarray(thermal_dt_s) > 0):
        raise ValueError("thermal_dt_s must be positive (dt=0 freezes the "
                         "RC state; dt<0 diverges it)")


def ondemand_index(opp_freq, num_opp, up_threshold, util, xp=np):
    """The ondemand transition on (C,) frequency domains — next OPP index.

    ``opp_freq``: (C, K) ascending per-domain OPP frequencies, rows padded by
    repeating the top level; ``num_opp``: (C,) real level counts;
    ``util``: (C,) window utilisation in [0, 1].  Above ``up_threshold`` jump
    to f_max; otherwise step down to the smallest OPP covering
    ``target = f_max · util / up_threshold``.  Pass ``xp=jnp`` to trace the
    same arithmetic inside the JAX kernel.
    """
    opp_freq = xp.asarray(opp_freq)
    num_opp = xp.asarray(num_opp)
    util = xp.asarray(util)
    top = num_opp - 1
    fmax = xp.take_along_axis(opp_freq, top[:, None], axis=1)[:, 0]
    target = fmax * xp.maximum(util, 0.0) / up_threshold
    covers = opp_freq >= (target[:, None] - 1e-9)
    down = xp.argmax(covers, axis=1).astype(num_opp.dtype)
    return xp.where(util > up_threshold, top, down)


def throttle_index(idx, temp_c, thermal_cap_c, xp=np):
    """Thermal-throttle override: clamp hot domains to their lowest OPP.

    ``idx``: (C,) proposed OPP indices; ``temp_c``: (C,) each domain's RC
    node temperature *after* the window's exact-step update; an infinite cap
    disables the override.
    """
    return xp.where(xp.asarray(temp_c) > thermal_cap_c,
                    xp.zeros_like(idx), idx)


# --------------------------------------------------------------------------
# Object-style governors (thin wrappers over the array-form policy)
# --------------------------------------------------------------------------

class Governor:
    name = "base"

    def initial_freq(self, pe_type: str) -> float:
        raise NotImplementedError

    def update(self, pe_type: str, cur_freq: float, utilization: float) -> float:
        """Return the new cluster frequency given window utilisation in [0,1]."""
        return cur_freq

    def policy(self) -> GovernorPolicy:
        """The array-form transition this governor implements (static here)."""
        return GovernorPolicy(dynamic=False)


class PerformanceGovernor(Governor):
    name = "performance"

    def initial_freq(self, pe_type: str) -> float:
        return OPP_TABLE[pe_type][-1][0]


class PowersaveGovernor(Governor):
    name = "powersave"

    def initial_freq(self, pe_type: str) -> float:
        return OPP_TABLE[pe_type][0][0]


class UserspaceGovernor(Governor):
    name = "userspace"

    def __init__(self, freq_ghz: Dict[str, float] | float = 1.0):
        self._freq = freq_ghz

    def initial_freq(self, pe_type: str) -> float:
        if isinstance(self._freq, dict):
            return self._freq[pe_type]
        return float(self._freq)


class OndemandGovernor(Governor):
    """Linux-style ondemand: sampling window + up-threshold.

    ``thermal_cap_c`` (default: uncapped) arms the thermal-throttle override;
    ``thermal_dt_s`` sets the RC integration step per window (defaults to the
    window itself — see :class:`GovernorPolicy`).  ``freq_caps`` (pe_type →
    max GHz, usually attached from the design point by
    ``Scenario.make_governor``) truncates the OPP ladder the transition
    ranges over — the hardware envelope dynamic policies must respect.
    """
    name = "ondemand"

    def __init__(self, up_threshold: float = 0.80,
                 sample_window_us: float = 50.0,
                 thermal_cap_c: float = math.inf,
                 thermal_dt_s: Optional[float] = None):
        self.up_threshold = up_threshold
        self.sample_window_us = sample_window_us
        self.thermal_cap_c = thermal_cap_c
        self.thermal_dt_s = (float(thermal_dt_s) if thermal_dt_s is not None
                             else sample_window_us * 1e-6)
        validate_policy_params(sample_window_us, up_threshold,
                               self.thermal_dt_s)
        self.freq_caps: Optional[Mapping[str, float]] = None
        self._ladders: Dict = {}       # (pe_type, caps) -> padded arrays

    def _ladder(self, pe_type: str):
        key = (pe_type, tuple(sorted(self.freq_caps.items()))
               if self.freq_caps else None)
        hit = self._ladders.get(key)
        if hit is None:
            opps, row, n = padded_ladder(pe_type, self.freq_caps)
            hit = self._ladders[key] = (opps, np.asarray([row]),
                                        np.asarray([n]))
        return hit

    def initial_freq(self, pe_type: str) -> float:
        return self._ladder(pe_type)[0][0]

    def update(self, pe_type: str, cur_freq: float, utilization: float) -> float:
        opps, row, num = self._ladder(pe_type)
        idx = ondemand_index(row, num, self.up_threshold,
                             np.asarray([float(utilization)]))
        return float(opps[int(idx[0])])

    def policy(self) -> GovernorPolicy:
        return GovernorPolicy(dynamic=True,
                              up_threshold=float(self.up_threshold),
                              sample_window_us=float(self.sample_window_us),
                              thermal_cap_c=float(self.thermal_cap_c),
                              thermal_dt_s=float(self.thermal_dt_s))


class ThrottleGovernor(OndemandGovernor):
    """Ondemand with the thermal cap armed by default: the closed DTPM loop
    (utilisation *and* temperature feed back into frequency)."""
    name = "throttle"

    def __init__(self, up_threshold: float = 0.80,
                 sample_window_us: float = 50.0,
                 thermal_cap_c: float = 60.0,
                 thermal_dt_s: Optional[float] = 0.05):
        super().__init__(up_threshold, sample_window_us, thermal_cap_c,
                         thermal_dt_s)


GOVERNORS = {
    "performance": PerformanceGovernor,
    "powersave": PowersaveGovernor,
    "userspace": UserspaceGovernor,
    "ondemand": OndemandGovernor,
    "throttle": ThrottleGovernor,
}


def get_governor(name: str, **kw) -> Governor:
    try:
        return GOVERNORS[name](**kw)
    except KeyError:
        raise KeyError(f"unknown governor {name!r}; have {sorted(GOVERNORS)}")
