"""Job generator — injects application instances following a distribution.

The paper: "The simulation is driven by the job generator which injects
instances of an application to the simulator following a given probability
distribution."  We support Poisson (exponential inter-arrival, parameterised
by an injection *rate* in jobs/ms as in Fig. 3) and deterministic arrivals.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """A realised workload: arrival time (us) + application index per job."""
    arrival_us: np.ndarray        # (num_jobs,) float32, sorted
    app_index: np.ndarray         # (num_jobs,) int32 into the app list
    app_names: Sequence[str]

    @property
    def num_jobs(self) -> int:
        return int(self.arrival_us.shape[0])


def poisson_trace(rate_jobs_per_ms: float, num_jobs: int, app_names: Sequence[str],
                  seed: int = 0, mix: Optional[Sequence[float]] = None) -> JobTrace:
    """Poisson arrivals at ``rate_jobs_per_ms``; app chosen from ``mix``."""
    rng = np.random.default_rng(seed)
    mean_gap_us = 1000.0 / float(rate_jobs_per_ms)
    gaps = rng.exponential(mean_gap_us, size=num_jobs).astype(np.float32)
    arrivals = np.cumsum(gaps, dtype=np.float32)
    probs = np.asarray(mix, dtype=np.float64) if mix is not None else None
    if probs is not None:
        probs = probs / probs.sum()
    idx = rng.choice(len(app_names), size=num_jobs, p=probs).astype(np.int32)
    return JobTrace(arrivals, idx, tuple(app_names))


def deterministic_trace(gap_us: float, num_jobs: int, app_names: Sequence[str],
                        seed: int = 0) -> JobTrace:
    arrivals = (np.arange(1, num_jobs + 1, dtype=np.float32)) * np.float32(gap_us)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(app_names), size=num_jobs).astype(np.int32)
    return JobTrace(arrivals, idx, tuple(app_names))


def rate_sweep(rates: Sequence[float], num_jobs: int, app_names: Sequence[str],
               seed: int = 0) -> List[JobTrace]:
    """One trace per injection rate (paper Fig. 3 x-axis)."""
    return [poisson_trace(r, num_jobs, app_names, seed=seed + i)
            for i, r in enumerate(rates)]
