"""Gradient compression: int8 quantisation with error feedback.

At 1000+ nodes the cross-pod (DCN) gradient all-reduce dominates step time
for pure-DP layouts; int8 with per-tensor scale cuts those bytes 4× vs f32
(2× vs bf16).  Error feedback (residual carried to the next step) keeps the
quantisation noise unbiased-in-the-limit; convergence is validated in
``tests/test_optim.py``.

Usage inside a train step (flag-controlled):
    g_q, new_err = ef_compress_grads(grads, err)     # quantise + EF
    # all-reduce happens on the int8 tree via psum/pjit resharding
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress_grads(grads: Pytree, err: Pytree) -> Tuple[Pytree, Pytree]:
    """Quantise (grads + err) to int8, return (dequantised grads, new err).

    The returned grads are what the optimizer sees (post round-trip, i.e.
    exactly what the wire carried); new err = input − round-trip.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress_int8(gf)
        rt = decompress_int8(q, s)
        return rt.astype(g.dtype), gf - rt

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in outs]),
            td.unflatten([o[1] for o in outs]))


def ef_init(params: Pytree, abstract: bool = False) -> Pytree:
    def z(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(z, params)
