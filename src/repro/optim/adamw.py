"""AdamW with fp32 master weights, built for sharded execution.

Optimizer state = {master, mu, nu, step}: master/mu/nu are fp32 trees with the
SAME sharding as the (bf16) parameters — since parameters are already sharded
over (fsdp × model) this is ZeRO-3-style fully-sharded optimizer state; no
chip holds more than params/|mesh| of it.  ``adamw_update`` consumes grads in
param dtype, updates in fp32, and emits a fresh bf16 param tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moment storage dtype: "bfloat16" halves optimizer-state HBM (the
    # update math stays f32; master weights stay f32) — the lever that puts
    # dbrx-132b train under the 16 GB/chip line at 256 chips
    moment_dtype: str = "float32"


def adamw_init(params: Pytree, abstract: bool = False,
               moment_dtype: str = "float32") -> Pytree:
    mdt = jnp.dtype(moment_dtype)

    def f32_like(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        # copy=True: when params are already f32, astype would alias the
        # param buffer and the train step would donate it twice
        return jnp.array(p, jnp.float32, copy=True)

    def zeros_like_m(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, mdt)
        return jnp.zeros(p.shape, mdt)

    step = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
            else jnp.zeros((), jnp.int32))
    return {
        "master": jax.tree.map(f32_like, params),
        "mu": jax.tree.map(zeros_like_m, params),
        "nu": jax.tree.map(zeros_like_m, params),
        "step": step,
    }


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Pytree, opt_state: Pytree,
                 params: Optional[Pytree] = None,
                 lr: Optional[jax.Array] = None) -> Tuple[Pytree, Pytree, jax.Array]:
    """Returns (new_params_in_param_dtype, new_opt_state, grad_norm).

    ``params`` is used only for its leaf dtypes (grads may be f32 after
    accumulation); defaults to grads' dtypes."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr_t = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1.0 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        w2 = w - lr_t * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                         + cfg.weight_decay * w)
        return m2.astype(mdt), v2.astype(mdt), w2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    mu = treedef.unflatten([o[0] for o in out])
    nu = treedef.unflatten([o[1] for o in out])
    master = treedef.unflatten([o[2] for o in out])

    dtype_src = params if params is not None else grads
    new_params = jax.tree.map(
        lambda w, p_old: w.astype(p_old.dtype), master, dtype_src)
    return new_params, {"master": master, "mu": mu, "nu": nu, "step": step}, gnorm


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)
