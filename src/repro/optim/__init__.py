from .adamw import AdamWConfig, adamw_init, adamw_update, apply_updates
from .schedule import cosine_schedule
from .compression import (compress_int8, decompress_int8, ef_compress_grads,
                          ef_init)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "apply_updates",
           "cosine_schedule", "compress_int8", "decompress_int8",
           "ef_compress_grads"]
