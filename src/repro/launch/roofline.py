"""Roofline analysis: three terms per (arch × shape × mesh) from the dry-run.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s            [s]
    memory term     = HLO_bytes_per_device / HBM_bw                 [s]
    collective term = Σ_kind wire_bytes_per_device / link_bw        [s]

Sources: per-device FLOPs/bytes come from the depth-extrapolated probe pair
(``dryrun._probe`` — XLA counts scanned bodies once, so the probes unroll);
collective wire bytes from the partitioned-HLO parse with ring factors.
MODEL_FLOPS (= 6·N_active·D analytics) / HLO_FLOPs flags remat/dispatch
waste.  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
Writes ``experiments/roofline.md`` and prints the table.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, Optional

from ..configs import ARCHITECTURES, SHAPES, get_config, get_shape
from ..models.transformer import stack_layout
from .dryrun import OUT_DIR
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

MD_OUT = OUT_DIR.parent / "roofline.md"


# ------------------------------------------------------------ analytic flops

def _matmul_params(cfg) -> Dict[str, float]:
    """Active matmul params per token, by component (MoE counts top-k only)."""
    D, H, KV, Dh, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    pat, reps, tail = stack_layout(cfg)
    blocks = list(pat) * reps + list(tail)
    attn_p = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
    if cfg.num_experts:
        mlp_p = (3 * D * cfg.moe_d_ff * cfg.top_k
                 + 3 * D * cfg.moe_d_ff * cfg.num_shared_experts
                 + D * cfg.num_experts)                     # router
    else:
        gated = cfg.act in ("silu", "geglu")
        mlp_p = (3 if gated else 2) * D * F
    mamba_p = 0.0
    if "mamba2" in blocks:
        Din, N = cfg.d_inner, cfg.ssm_state
        mamba_p = D * Din + D * (Din + 2 * N) + D * cfg.ssm_heads + Din * D
    rglru_p = 0.0
    if "rglru" in blocks:
        W = cfg.lru_width
        rglru_p = 2 * D * W + 2 * W * (W // max(cfg.num_heads, 1)) + W * D
    out = {"attn_proj": 0.0, "ffn": 0.0, "rec": 0.0, "enc": 0.0}
    for b in blocks:
        if b in ("global", "local", "enc", "xdec"):
            out["attn_proj"] += attn_p * (2 if b == "xdec" else 1)
            out["ffn"] += mlp_p
        elif b == "rglru":
            out["rec"] += rglru_p
            out["ffn"] += mlp_p
        elif b == "mamba2":
            out["rec"] += mamba_p
    if cfg.is_encoder_decoder:
        out["enc"] = (attn_p + mlp_p) * cfg.num_encoder_layers
    out["head"] = cfg.d_model * cfg.padded_vocab
    return out


def _attn_score_flops(cfg, S: int, kv_len: int, batch: int) -> float:
    """Softmax-path FLOPs (QK^T + PV) for one forward, all layers."""
    pat, reps, tail = stack_layout(cfg)
    blocks = list(pat) * reps + list(tail)
    H, Dh = cfg.num_heads, cfg.head_dim
    total = 0.0
    for b in blocks:
        if b in ("global", "xdec"):
            total += 4.0 * batch * S * kv_len * H * Dh
            if b == "xdec":
                total += 4.0 * batch * S * min(kv_len, 4096) * H * Dh
        elif b == "local":
            total += 4.0 * batch * S * min(cfg.window_size, kv_len) * H * Dh
    return total


def model_flops(arch: str, shape_name: str) -> float:
    """Global useful FLOPs per step: 6·N_active·tokens (+ attention)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    parts = _matmul_params(cfg)
    n_active = sum(parts.values())
    B, S = shape.global_batch, shape.seq_len
    H, Dh = cfg.num_heads, cfg.head_dim
    enc_attn = (4.0 * B * S * S * H * Dh * cfg.num_encoder_layers
                if cfg.is_encoder_decoder else 0.0)
    if shape.kind == "train":
        return (6.0 * n_active * B * S
                + 3.0 * (_attn_score_flops(cfg, S, S, B) + enc_attn))
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            # encoder runs the full S frames; the decoder only prefills the
            # prompt (64 tokens) + cross-attends the encoder output
            from .specs import SEAMLESS_PREFILL_PROMPT as DEC
            dec_p = n_active - parts["enc"] - parts["head"]
            attn = (enc_attn
                    + 4.0 * B * DEC * DEC * H * Dh * cfg.num_layers
                    + 4.0 * B * DEC * S * H * Dh * cfg.num_layers)
            return (2.0 * parts["enc"] * B * S + 2.0 * dec_p * B * DEC
                    + 2.0 * parts["head"] * B * DEC + attn)
        return 2.0 * n_active * B * S + _attn_score_flops(cfg, S, S, B)
    # decode: one token over a kv_len cache (the encoder does not run)
    dec_active = n_active - parts["enc"]
    return (2.0 * dec_active * B + _attn_score_flops(cfg, 1, S, B))


# ------------------------------------------------------------ table builder

def load_cell(arch: str, shape: str, mesh: str) -> Optional[dict]:
    p = OUT_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def cell_terms(rec: dict) -> Optional[dict]:
    if not rec.get("runnable") or "extrapolated" not in rec:
        return None
    ex = rec["extrapolated"]
    nd = rec["num_devices"]
    t_c = ex["flops"] / PEAK_FLOPS_BF16
    t_m = ex["bytes"] / HBM_BW
    t_n = sum(ex["wire"].values()) / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"]) / nd
    hlo = max(ex["flops"], 1e-9)
    mem = rec.get("memory_analysis", {})
    hbm_gb = (mem.get("temp_size_in_bytes", 0)
              + mem.get("argument_size_in_bytes", 0)) / 1e9
    bound = max(t_c, t_m, t_n)
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_n, dominant=dom,
                model_flops_frac=mf / hlo, hbm_gb=hbm_gb,
                roofline_frac=t_c / bound if bound > 0 else 0.0)


_ADVICE = {
    "compute": "compute-bound: cut redundant FLOPs (remat policy, causal-"
               "block skipping, MoE dispatch) or it is already near-roofline",
    "memory": "HBM-bound: raise arithmetic intensity — fuse attention "
              "(Pallas flash kernel), int8/KV-cache quantisation, larger "
              "per-chunk tiles",
    "collective": "ICI-bound: reshard to cut all-gathers (bigger per-device "
                  "blocks), overlap collectives with compute, or compress "
                  "the gradient/activation wire format",
}


def build_table(mesh: str = "pod16x16") -> str:
    rows = []
    for arch in sorted(ARCHITECTURES):
        for shape in sorted(SHAPES):
            rec = load_cell(arch, shape, mesh)
            if rec is None:
                continue
            if not rec.get("runnable"):
                rows.append((arch, shape, None, rec.get("skip_reason", "")))
                continue
            rows.append((arch, shape, cell_terms(rec), ""))

    md = [f"## Roofline — mesh {mesh} (per-device terms, seconds/step)\n",
          "| arch | shape | compute s | memory s | collective s | dominant |"
          " MODEL/HLO | HBM GB | next lever |",
          "|---|---|---|---|---|---|---|---|---|"]
    for arch, shape, t, skip in rows:
        if t is None:
            md.append(f"| {arch} | {shape} | — | — | — | skipped | — | — |"
                      f" {skip} |")
            continue
        md.append(
            f"| {arch} | {shape} | {t['t_compute']:.3e} | {t['t_memory']:.3e}"
            f" | {t['t_collective']:.3e} | **{t['dominant']}** |"
            f" {t['model_flops_frac']:.2f} | {t['hbm_gb']:.1f} |"
            f" {_ADVICE[t['dominant']]} |")
    return "\n".join(md) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    table = build_table(args.mesh)
    MD_OUT.parent.mkdir(parents=True, exist_ok=True)
    MD_OUT.write_text(table)
    print(table)
    print(f"written to {MD_OUT}")


if __name__ == "__main__":
    main()
