"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the 1 real CPU device.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod
config is 2 pods = 512 chips with the leading ``pod`` axis mapped onto DCN.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np

from ..sharding import rules_multi_pod, rules_single_pod


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke runs of the pjit code path."""
    types = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=types)


def rules_for(mesh, *, batch_size: Optional[int] = None,
              kind: str = "train") -> Dict[str, object]:
    """Logical-axis rules matching a mesh; drops batch sharding when the
    global batch cannot be divided over the DP axes (e.g. long_500k B=1).

    ``kind='decode'`` uses the weight-stationary serving layout: batch
    activations replicate over the data axis while weights stay resident
    FSDP+TP-sharded, so matmuls partial-sum over tiny activations instead of
    all-gathering the weights per token (adopted after §Perf iteration on
    dbrx-132b decode_32k: collective term 0.662 s -> 0.008 s, 10.7× better
    step bound).  The KV cache keeps its own batch axis (``kv_batch``)."""
    multi = "pod" in mesh.axis_names
    rules = rules_multi_pod() if multi else rules_single_pod()
    if kind == "decode":
        rules["batch"] = None
    elif kind == "train_pp" and multi:
        # pipeline mode: `pod` carries stages, so DP/FSDP stay intra-pod
        rules["batch"] = "data"
        rules["kv_batch"] = "data"
        rules["fsdp"] = "data"
    if batch_size is not None:
        dp = mesh.shape["data"] * (mesh.shape["pod"] if multi else 1)
        if batch_size % dp != 0:
            b = None if batch_size < dp else "data"
            if batch_size % mesh.shape["data"] != 0:
                b = None
            if kind != "decode":
                rules["batch"] = b
            rules["kv_batch"] = b
    # degenerate host mesh: keep annotations harmless
    if mesh.shape.get("model", 1) == 1 and mesh.shape.get("data", 1) == 1:
        rules = {k: None for k in rules}
    return rules


# Hardware constants for the roofline (TPU v5e) --------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-chip usable)
DCN_BW = 25e9                     # bytes/s per chip cross-pod (2 pods)
