import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Perf hillclimbing harness (§Perf): hypothesis → change → measure.

Each VARIANT of a cell re-lowers the full production step with config
overrides (and optionally patched sharding rules), re-derives the three
roofline terms, and records before/after against the dry-run baseline.
Results land in ``experiments/hillclimb/``; EXPERIMENTS.md §Perf narrates.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell moe
"""
import argparse
import json
from pathlib import Path

from .dryrun import OUT_DIR, build_cell, collective_bytes, _mem_dict, _probe, \
    extrapolate
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from .roofline import cell_terms, load_cell, model_flops

HC_DIR = OUT_DIR.parent / "hillclimb"

# variant = (name, cfg overrides, rules patch)
CELLS = {
    # (c) most paper-representative: MoE token dispatch IS the paper's
    # scheduling problem (tasks -> heterogeneous executors)
    "moe": ("deepseek-moe-16b", "train_4k", [
        ("moe_sort", {"moe_impl": "sort"}, None),
        ("moe_group_512", {"moe_group_size": 512}, None),
        ("moe_group_8192", {"moe_group_size": 8192}, None),
        ("moe_sort_selremat", {"moe_impl": "sort", "remat": "selective"}, None),
    ]),
    # (b) most collective-bound: 132B weights all-gathered per decoded token
    "decode": ("dbrx-132b", "decode_32k", [
        ("kv_int8", {"kv_cache_dtype": "int8"}, None),
        # weight-stationary decode: replicate the (tiny) batch activations,
        # keep weights resident-sharded; matmuls partial-sum over fsdp
        ("weight_stationary", {}, {"batch": None}),
        ("ws_kv_int8", {"kv_cache_dtype": "int8"}, {"batch": None}),
    ]),
    # (a) worst roofline fraction: B=1 long-context decode on a 130M SSM —
    # fixed collective latency swamps nanoseconds of compute
    "long": ("mamba2-130m", "long_500k", [
        ("tp_off", {}, {"model": None, "expert": None, "kv_seq": None}),
        # right-size the deployment: a 4×4 serving slice (DS3-autotuner move)
        ("slice_4x4", {}, None, (4, 4)),
        ("slice_1x4", {}, None, (1, 4)),
    ]),
}

EXTRA_MOE = [
    ("group512_selremat", {"moe_group_size": 512, "remat": "selective"}, None),
    ("group512_bf16scores", {"moe_group_size": 512,
                             "attn_scores_f32": False}, None),
]
CELLS["moe"][2].extend(EXTRA_MOE)


def measure(arch, shape, overrides=None, rules_patch=None, probes=True,
            mesh_shape=None):
    lowered, mesh, _, _ = build_cell(arch, shape, False, overrides=overrides,
                                     rules_patch=rules_patch,
                                     mesh_shape=mesh_shape)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    mem = _mem_dict(compiled)
    cres, cwire, ccounts = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape, "mesh": "pod16x16", "runnable": True,
        "num_devices": int(mesh.size),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "memory_analysis": mem, "collective_bytes": cres,
        "collective_wire_bytes": cwire, "collective_counts": ccounts,
    }
    if probes:
        ov = dict(overrides or {})
        p1 = _probe_with(arch, shape, 1, ov, rules_patch, mesh_shape)
        p2 = _probe_with(arch, shape, 2, ov, rules_patch, mesh_shape)
        rec["extrapolated"] = extrapolate(arch, p1, p2)
    return rec


def _probe_with(arch, shape, repeats, overrides, rules_patch,
                mesh_shape=None):
    from ..configs import get_config
    cfg = get_config(arch)
    patlen = len(cfg.block_pattern) if not cfg.is_encoder_decoder else 1
    ov = dict(overrides)
    ov.update({"num_layers": repeats * patlen, "scan_layers": False,
               "attn_impl": "blocked_unroll"})
    if cfg.is_encoder_decoder:
        ov["num_encoder_layers"] = repeats
    lowered, _, _, _ = build_cell(arch, shape, False, overrides=ov,
                                  probe_accum=1, rules_patch=rules_patch,
                                  mesh_shape=mesh_shape)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    cres, cwire, _ = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": cres, "wire": cwire}


def fmt(rec):
    t = cell_terms(rec)
    mem = rec.get("memory_analysis", {})
    hbm = (mem.get("temp_size_in_bytes", 0)
           + mem.get("argument_size_in_bytes", 0)) / 1e9
    if t is None:
        return f"hbm={hbm:.1f}GB (no probes)"
    return (f"comp={t['t_compute']:.3e}s mem={t['t_memory']:.3e}s "
            f"coll={t['t_collective']:.3e}s dom={t['dominant']} "
            f"useful={t['model_flops_frac']:.2f} hbm={hbm:.1f}GB")


def run_cell_variants(key: str):
    arch, shape, variants = CELLS[key]
    HC_DIR.mkdir(parents=True, exist_ok=True)
    base = load_cell(arch, shape, "pod16x16")
    print(f"=== {key}: {arch} × {shape} ===")
    print(f"baseline       : {fmt(base)}")
    results = {"baseline": base}
    for var in variants:
        name, ov, rp = var[0], var[1], var[2]
        ms = var[3] if len(var) > 3 else None
        try:
            rec = measure(arch, shape, overrides=ov or None, rules_patch=rp,
                          mesh_shape=ms)
            results[name] = rec
            (HC_DIR / f"{arch}__{shape}__{name}.json").write_text(
                json.dumps(rec, indent=1))
            print(f"{name:<15}: {fmt(rec)}")
        except Exception as e:                       # noqa: BLE001
            print(f"{name:<15}: FAILED {e!r}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS) + ["all"], default="all")
    args = ap.parse_args()
    for key in (CELLS if args.cell == "all" else [args.cell]):
        run_cell_variants(key)


if __name__ == "__main__":
    main()
