"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --preset tiny --steps 200 --batch 8 --seq 256

Production behaviours demonstrated (and tested in tests/test_train_driver.py):
  * checkpoint/restart: atomic manifests, async save every --ckpt-every
    steps, resume from the latest checkpoint (``--resume``);
  * simulated preemption: ``--fail-at N`` raises mid-run; the retry loop
    restores and continues — final weights are bit-identical to an
    uninterrupted run (deterministic data addressing);
  * straggler watchdog: per-step wall times are tracked and steps slower
    than ``straggler_factor ×`` the running median are flagged (on real
    fleets this feeds the DS3/ETF re-scheduler — see launch/autotune.py);
  * gradient compression (``--compress-grads``) and microbatch accumulation
    (``--accum``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, reduced
from ..data import SyntheticLMPipeline
from ..models import build_model
from ..optim import AdamWConfig
from ..sharding import use_mesh
from .mesh import make_host_mesh, make_production_mesh, rules_for
from .steps import init_opt_state, make_train_step


class StragglerWatchdog:
    """Flags steps whose wall time exceeds factor × running median."""

    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.times = []
        self.events = []

    def observe(self, step: int, dt: float):
        self.times.append(dt)
        if len(self.times) > self.warmup:
            med = float(np.median(self.times[-50:]))
            if dt > self.factor * med:
                self.events.append({"step": step, "dt": dt, "median": med})
                return True
        return False


def train(arch: str = "mamba2-130m", preset: str = "tiny", steps: int = 50,
          batch: int = 8, seq: int = 256, lr: float = 3e-3,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
          resume: bool = False, fail_at: Optional[int] = None,
          accum: int = 1, compress_grads: bool = False, seed: int = 0,
          log_every: int = 10, production_mesh: bool = False):
    cfg = get_config(arch)
    if preset == "tiny":
        cfg = reduced(cfg)
    cfg = cfg.replace(remat="none" if preset == "tiny" else "full")
    assert cfg.family not in ("vlm", "audio") or preset == "tiny", \
        "frontend stubs: driver trains LM families at full scale"

    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    rules = rules_for(mesh, batch_size=batch)

    with use_mesh(mesh, rules):
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(seed))
        opt_state = init_opt_state(params, compress_grads=compress_grads)
        pipe = SyntheticLMPipeline(cfg.vocab_size, batch, seq, seed=seed)
        step_fn = jax.jit(make_train_step(
            model, AdamWConfig(lr=lr), accum_steps=accum,
            compress_grads=compress_grads), donate_argnums=(0, 1))

        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start = 0
        if mgr and resume and mgr.latest_step() is not None:
            state, meta = mgr.restore()
            params, opt_state = state["params"], state["opt"]
            pipe.load_state_dict(meta["data"])
            start = meta["step"]
            print(f"[train] resumed from step {start}")

        watchdog = StragglerWatchdog()
        losses = []
        for step in range(start, steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected preemption at step {step}")
            t0 = time.time()
            batch_np = pipe.batch_at(step)
            pipe.state.step = step + 1
            params, opt_state, metrics = step_fn(params, opt_state, batch_np)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if watchdog.observe(step, dt):
                print(f"[train] straggler flagged at step {step}: {dt:.2f}s")
            if step % log_every == 0 or step == steps - 1:
                toks = batch * seq / max(dt, 1e-9)
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"{dt*1e3:7.1f} ms/step {toks:9.0f} tok/s")
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         meta={"data": pipe.state_dict()}, blocking=False)
        if mgr:
            mgr.save(steps, {"params": params, "opt": opt_state},
                     meta={"data": pipe.state_dict()})
            mgr.wait()
        return params, losses, watchdog


def train_with_retries(max_retries: int = 3, **kw):
    """The fleet-facing entry: restart-from-checkpoint on any failure."""
    attempt = 0
    while True:
        try:
            return train(**kw)
        except RuntimeError as e:
            attempt += 1
            print(f"[train] failure: {e}; retry {attempt}/{max_retries}")
            if attempt > max_retries:
                raise
            kw = dict(kw, resume=True, fail_at=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    train_with_retries(
        arch=args.arch, preset=args.preset, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume, fail_at=args.fail_at,
        accum=args.accum, compress_grads=args.compress_grads,
        production_mesh=args.production_mesh)


if __name__ == "__main__":
    main()
