import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (train / prefill / decode),
lowers it against ShapeDtypeStruct stand-ins with full production shardings,
compiles it for the 16×16 single-pod or 2×16×16 multi-pod mesh, and records:

  * ``compiled.memory_analysis()``   — proves the cell fits per-device HBM
  * ``compiled.cost_analysis()``     — HLO FLOPs / bytes for §Roofline
  * collective-op operand bytes parsed from the partitioned HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — cost_analysis does not report them.

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``;
``launch/roofline.py`` aggregates them into EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHITECTURES, SHAPES, cell_is_runnable, get_config, get_shape
from ..models import build_model
from ..optim import AdamWConfig
from ..sharding import use_mesh
from .mesh import make_production_mesh, rules_for
from .specs import batch_specs, cache_specs, named
from .steps import init_opt_state, make_prefill_step, make_serve_step, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_OP_RE = re.compile(r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([a-z0-9-]+)\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str):
    """Per-collective-kind byte totals from the partitioned HLO text.

    Post-optimization HLO drops operand type annotations, so sizes are taken
    from the RESULT shape and converted to per-device wire bytes with the
    standard ring-algorithm factors:
        all-gather          wire ≈ result            (receives all shards)
        all-reduce          wire ≈ 2 × result        (RS + AG phases)
        reduce-scatter      wire ≈ result × group    (sends full operand)
        all-to-all          wire ≈ result
        collective-permute  wire ≈ result
    Async ``-start``/``-done`` pairs count once (tuple result: max component).
    """
    res = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _OP_RE.search(s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
        if kind is None:
            continue
        sizes = [_shape_bytes(dt, dims)
                 for dt, dims in _SHAPE_RE.findall(shape_str)
                 if dt in _DTYPE_BYTES]
        if not sizes:
            continue
        nbytes = max(sizes)                     # tuple result: the gathered buf
        gm = _GROUPS_RE.search(s)
        group = int(gm.group(2)) if gm else 1
        res[kind] += nbytes
        counts[kind] += 1
        if kind == "all-reduce":
            wire[kind] += 2 * nbytes
        elif kind == "reduce-scatter":
            wire[kind] += nbytes * group
        else:
            wire[kind] += nbytes
    return res, wire, counts


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    if ma is None:
        return {}
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict = None, probe_accum: int = None,
               rules_patch: dict = None, mesh_shape: tuple = None):
    """Returns the lowered computation (+ mesh, cfg, shape) for a cell."""
    import jax as _jax
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind == "train":
        # baseline: full per-superblock activation checkpointing
        cfg = cfg.replace(remat="full")
    if overrides:
        cfg = cfg.replace(**overrides)
    if mesh_shape is not None:       # e.g. a small serving slice (4,4)
        types = (_jax.sharding.AxisType.Auto,) * len(mesh_shape)
        mesh = _jax.make_mesh(mesh_shape, ("data", "model"), axis_types=types)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh, batch_size=shape.global_batch, kind=shape.kind)
    if rules_patch:
        rules.update(rules_patch)

    with use_mesh(mesh, rules):
        model = build_model(cfg)
        params_sds = model.abstract_params()
        params_ps = named(mesh, model.param_pspecs())
        b_sds, b_ps = batch_specs(cfg, shape)
        b_ns = named(mesh, b_ps)

        if shape.kind == "train":
            # microbatch so each data shard sees 4 sequences per microbatch
            # (1 for wide models — activation bytes scale with d_model;
            # dbrx-132b measured 29 GB at 4 seqs vs 11 GB at 1)
            dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
            per_shard = max(1, shape.global_batch // dp)
            per_micro = 4 if cfg.d_model < 4096 else 1
            accum = probe_accum or max(1, per_shard // per_micro)
            # bf16 Adam moments: halves optimizer HBM (update math stays f32);
            # wide models also accumulate microbatch grads in bf16
            adt = "bfloat16" if cfg.d_model >= 4096 else "float32"
            step = make_train_step(model, AdamWConfig(moment_dtype="bfloat16"),
                                   accum_steps=accum, accum_dtype=adt)
            opt_sds = init_opt_state(params_sds, abstract=True,
                                     moment_dtype="bfloat16")
            opt_ps = jax.tree.map(
                lambda l, s=None: None, opt_sds)  # placeholder, set below
            # optimizer state shards exactly like params; step is replicated
            opt_ps = {
                "master": params_ps, "mu": params_ps, "nu": params_ps,
                "step": NamedSharding(mesh, P()),
            }
            fn = jax.jit(step,
                         in_shardings=(params_ps, opt_ps, b_ns),
                         out_shardings=(params_ps, opt_ps, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_sds, opt_sds, b_sds)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, max_len=shape.seq_len)
            _, c_ps = cache_specs(model, shape)
            c_ns = named(mesh, c_ps)
            fn = jax.jit(step, in_shardings=(params_ps, b_ns),
                         out_shardings=(None, c_ns))
            lowered = fn.lower(params_sds, b_sds)
        else:                                   # decode
            step = make_serve_step(model)
            c_sds, c_ps = cache_specs(model, shape)
            c_ns = named(mesh, c_ps)
            tok_ns = b_ns["tokens"]
            pos_ns = NamedSharding(mesh, P())
            fn = jax.jit(step,
                         in_shardings=(params_ps, c_ns, tok_ns, pos_ns),
                         out_shardings=(None, None, c_ns),
                         donate_argnums=(1,))
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(params_sds, c_sds, b_sds["tokens"], pos_sds)
        return lowered, mesh, cfg, shape


def _probe(arch: str, shape_name: str, multi_pod: bool, repeats: int) -> dict:
    """Unrolled shallow-depth probe: XLA's cost_analysis counts a scanned
    layer body ONCE (not × trip count), so roofline terms come from two
    unrolled probes (R=1, R=2) extrapolated linearly in depth."""
    from ..models.transformer import stack_layout
    cfg = get_config(arch)
    patlen = len(cfg.block_pattern) if not cfg.is_encoder_decoder else 1
    # blocked_unroll: attention chunks unrolled so every one is counted
    ov = {"num_layers": repeats * patlen, "scan_layers": False,
          "attn_impl": "blocked_unroll"}
    if cfg.is_encoder_decoder:
        ov["num_encoder_layers"] = repeats
    lowered, mesh, _, _ = build_cell(arch, shape_name, multi_pod, overrides=ov,
                                     probe_accum=1)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    cres, cwire, _ = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": cres, "wire": cwire}


def extrapolate(arch: str, p1: dict, p2: dict) -> dict:
    """Linear-in-depth extrapolation of the probe pair to full depth."""
    cfg = get_config(arch)
    from ..models.transformer import stack_layout
    pat, reps, tail = stack_layout(cfg)
    eff_reps = reps + len(tail) / len(pat)      # tail ≈ fraction of superblock

    def lin(v1, v2):
        body = v2 - v1
        return v1 + body * (eff_reps - 1)

    out = {"flops": lin(p1["flops"], p2["flops"]),
           "bytes": lin(p1["bytes"], p2["bytes"]),
           "coll": {k: lin(p1["coll"][k], p2["coll"][k]) for k in p1["coll"]},
           "wire": {k: lin(p1["wire"][k], p2["wire"][k]) for k in p1["wire"]}}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = OUT_DIR, save: bool = True,
             probes: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_is_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "runnable": ok}
    if not ok:
        rec["skip_reason"] = reason
        print(f"[dryrun] SKIP {arch} × {shape_name} × {mesh_name}: {reason}")
    else:
        t0 = time.time()
        lowered, mesh, _, _ = build_cell(arch, shape_name, multi_pod)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis() or {}
        mem = _mem_dict(compiled)
        cbytes, cwire, ccounts = collective_bytes(compiled.as_text())
        rec.update(
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            num_devices=int(mesh.size),
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            cost_analysis={k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float))},
            memory_analysis=mem,
            collective_bytes=cbytes,
            collective_wire_bytes=cwire,
            collective_counts=ccounts,
        )
        if probes:
            p1 = _probe(arch, shape_name, multi_pod, 1)
            p2 = _probe(arch, shape_name, multi_pod, 2)
            rec["probe_r1"], rec["probe_r2"] = p1, p2
            rec["extrapolated"] = extrapolate(arch, p1, p2)
        print(f"[dryrun] OK {arch} × {shape_name} × {mesh_name} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"flops/dev={rec.get('extrapolated', {}).get('flops', rec['flops']):.3e} "
              f"coll={sum(cbytes.values()):.3e}B")
        print(f"  memory_analysis: {mem}")
    if save:
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
        path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHITECTURES) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    failures = []
    for a, s, m in cells:
        mesh_name = "pod2x16x16" if m else "pod16x16"
        path = OUT_DIR / f"{a}__{s}__{mesh_name}.json"
        if args.skip_existing and path.exists():
            print(f"[dryrun] cached {path.name}")
            continue
        try:
            run_cell(a, s, m)
        except Exception as e:  # noqa: BLE001 — sweep must report all failures
            failures.append((a, s, mesh_name, repr(e)))
            print(f"[dryrun] FAIL {a} × {s} × {mesh_name}: {e!r}")
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\n[dryrun] all cells passed")


if __name__ == "__main__":
    main()
