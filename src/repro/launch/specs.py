"""ShapeDtypeStruct stand-ins + sharding specs for every model input.

Nothing here allocates device memory: the dry-run lowers against these
abstract values (the shannon/kernels pattern — weak-type-correct, shardable).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import Model
from ..sharding import logical_to_pspec

SEAMLESS_DECODE_ENC_LEN = 4096     # encoder length backing decode-shape cells
SEAMLESS_PREFILL_PROMPT = 64      # decoder prompt tokens during prefill


def _bt(*axes):
    return logical_to_pspec(axes)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, P]]:
    """(ShapeDtypeStructs, PartitionSpecs) for the data batch."""
    B, S = shape.global_batch, shape.seq_len
    i32, act = jnp.int32, jnp.dtype(cfg.dtype)
    sds: Dict[str, Any] = {}
    ps: Dict[str, Any] = {}

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            n = cfg.num_prefix_tokens
            sds["patch_embeds"] = jax.ShapeDtypeStruct((B, n, cfg.d_model), act)
            ps["patch_embeds"] = _bt("batch", None, None)
            sds["tokens"] = jax.ShapeDtypeStruct((B, S - n), i32)
        elif cfg.frontend == "audio":
            sds["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), act)
            ps["frames"] = _bt("batch", None, None)
            dec = SEAMLESS_PREFILL_PROMPT if shape.kind == "prefill" else S
            sds["tokens"] = jax.ShapeDtypeStruct((B, dec), i32)
        else:
            sds["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        ps["tokens"] = _bt("batch", None)
        if shape.kind == "train":
            sds["labels"] = jax.ShapeDtypeStruct(sds["tokens"].shape, i32)
            ps["labels"] = _bt("batch", None)
    else:                                   # decode
        sds["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        ps["tokens"] = _bt("batch", None)
    return sds, ps


def cache_specs(model: Model, shape: ShapeConfig
                ) -> Tuple[Any, Any]:
    """(abstract cache tree, PartitionSpec tree).  Batch dim is index 1 for
    scan-stacked leaves ('stack' subtree), else index 0."""
    cfg = model.cfg
    enc_len = (min(shape.seq_len, SEAMLESS_DECODE_ENC_LEN)
               if cfg.is_encoder_decoder else 0)
    cache = model.init_cache(shape.global_batch, shape.seq_len,
                             enc_len=enc_len, abstract=True)

    def spec_for(path, leaf):
        keys = {getattr(k, "key", None) for k in path}
        stacked = "stack" in keys
        bdim = 1 if stacked else 0
        axes = [None] * leaf.ndim
        axes[bdim] = "kv_batch"
        # KV caches (B, L, KV, Dh): KV heads are often too few to TP-shard,
        # so the SEQUENCE dim shards over the otherwise-idle `model` axis —
        # flash-decode style distributed attention (partial softmax per shard
        # + tiny cross-shard combine), 16× less cache per chip.
        if ({"kv", "ck", "cv"} & keys) and leaf.ndim >= bdim + 4:
            axes[bdim + 1] = "model"
        return logical_to_pspec(axes)

    specs = jax.tree_util.tree_map_with_path(spec_for, cache)
    return cache, specs


def named(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        tree, is_leaf=lambda x: isinstance(x, P))
