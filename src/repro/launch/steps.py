"""Step functions: train (fwd+bwd+AdamW), prefill, decode — the units the
dry-run lowers and the drivers run.

``make_train_step`` options:
  * ``accum_steps`` — microbatch gradient accumulation via ``lax.scan``
    (memory lever at fixed global batch);
  * ``compress_grads`` — int8 error-feedback gradient compression applied to
    the gradient tree before the optimizer (the wire format of the cross-pod
    all-reduce at 1000-node scale; the EF residual lives in opt_state).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import Model
from ..optim import (AdamWConfig, adamw_init, adamw_update, ef_compress_grads,
                     ef_init)

Pytree = Any


def init_opt_state(params: Pytree, abstract: bool = False,
                   compress_grads: bool = False,
                   moment_dtype: str = "float32") -> Pytree:
    st = adamw_init(params, abstract=abstract, moment_dtype=moment_dtype)
    if compress_grads:
        st["err"] = ef_init(params, abstract=abstract)
    return st


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    accum_steps: int = 1, compress_grads: bool = False,
                    accum_dtype: str = "float32"):
    adt = jnp.dtype(accum_dtype)

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), b)

        def body(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_loss + l,
                    jax.tree.map(lambda a, x: a + x.astype(adt),
                                 acc_g, g)), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (tl, tg), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_g),
                                   micro(batch))
        inv = 1.0 / accum_steps
        return tl * inv, jax.tree.map(lambda g: (g.astype(jnp.float32)
                                                 * inv), tg)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if compress_grads:
            grads, new_err = ef_compress_grads(grads, opt_state["err"])
        new_params, new_opt, gnorm = adamw_update(
            opt_cfg, grads, {k: v for k, v in opt_state.items() if k != "err"},
            params=params)
        if compress_grads:
            new_opt["err"] = new_err
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, max_len)
        return logits, cache
    return prefill_step


def make_serve_step(model: Model, greedy: bool = True):
    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step
