"""RG-LRU linear recurrence (TPU Pallas).

h_t = a_t ⊙ h_{t-1} + x_t, evaluated as a sequential in-VMEM scan — the same
design as the official RecurrentGemma TPU kernel: the recurrence is memory
bound, so the win is streaming (a, x) tiles through VMEM once while the
hidden state stays resident in scratch; the time loop is a VPU fori_loop over
rows of the tile.

Grid (B, W/bw, S/c) with the sequence dim innermost (scratch h carries
across sequence blocks, resets per (batch, width-block)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rg_lru_kernel(a_ref, x_ref, y_ref, h_ref, *, chunk: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)            # (c, bw)
    x = x_ref[0].astype(jnp.float32)            # (c, bw)

    def body(t, carry):
        h = carry
        h = a[t] * h + x[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h


def rg_lru_bsw(a, x, *, block_w: int = 512, block_s: int = 128,
               interpret: bool = False):
    """a, x: (B, S, W) f32 -> h: (B, S, W) f32 (full hidden trajectory)."""
    B, S, W = a.shape
    bw = min(block_w, W)
    c = min(block_s, S)
    assert W % bw == 0 and S % c == 0, (W, bw, S, c)
    grid = (B, W // bw, S // c)

    kernel = functools.partial(_rg_lru_kernel, chunk=c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, bw), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, c, bw), lambda b, w, s: (b, s, w)),
        ],
        out_specs=pl.BlockSpec((1, c, bw), lambda b, w, s: (b, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, x)
