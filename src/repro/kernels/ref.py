"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: float = 1.0):
    """q: (B,Sq,H,Dh); k,v: (B,Sk,KV,Dh) -> (B,Sq,H,Dh).  Full softmax."""
    B, Sq, H, Dh = q.shape
    KV, Sk = k.shape[2], k.shape[1]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


def decode_attention_ref(q, k, v, valid, *, softcap: Optional[float] = None,
                         scale: float = 1.0):
    """q: (B,1,H,Dh); k,v: (B,L,KV,Dh); valid: (L,) or (B,L) -> (B,1,H,Dh)."""
    B, _, H, Dh = q.shape
    KV, L = k.shape[2], k.shape[1]
    g = H // KV
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (B, L))
    qg = q.reshape(B, 1, KV, g, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm):
    """Naive sequential SSM recurrence (the SSD ground truth).

    x: (B,L,H,P); dt: (B,L,H) f32; A: (H,); Bm,Cm: (B,L,N).
    h_t = h_{t-1}·exp(A·dt_t) + dt_t·x_t⊗B_t ;  y_t = h_t·C_t
    Returns (y: (B,L,H,P), h_last: (B,H,P,N)) in f32.
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A)                    # (B,H)
        upd = dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        h = h * decay[..., None, None] + upd        # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h0,
        (xf.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), h_last


def rg_lru_ref(a, x):
    """Sequential reference for h_t = a_t·h_{t-1} + x_t.  a,x: (B,S,W) f32."""
    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), x.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
