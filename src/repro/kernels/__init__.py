"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three pieces:
  * ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
    VMEM tiling (TPU target; validated with interpret=True on CPU);
  * ``ops.py``    — the jitted public wrapper the model code calls;
  * ``ref.py``    — the pure-jnp oracle it is tested against.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
