"""Mamba2 SSD chunk scan (TPU Pallas).

Grid (B, H, nc) with the chunk index innermost: the inter-chunk SSM state
(P × N) lives in VMEM scratch and is carried across grid steps — the
TPU-native equivalent of the CUDA chunked-scan in the Mamba2 paper (grid
iterations on TPU run sequentially minor-to-major, so scratch accumulation
over the chunk axis is the idiomatic carry).

Per chunk (c = chunk length):
    y_off  = (C · exp(cs)) @ state            inter-chunk contribution
    y_diag = tril(C Bᵀ ⊙ decay) ⊙ dt @ x      intra-chunk (MXU matmuls)
    state  = state · exp(cs_last) + (x ⊙ seg)ᵀ B
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, cs_ref, b_ref, c_ref, y_ref, hlast_ref,
                state_ref, *, chunk: int):
    z = pl.program_id(2)
    nz = pl.num_programs(2)

    @pl.when(z == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (c, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (c,)
    cs = cs_ref[0, 0, 0].astype(jnp.float32)     # (c,)  cumsum(A·dt)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (c, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (c, N)
    state = state_ref[...]                       # (P, N)

    # inter-chunk: y_off[i] = (C_i · exp(cs_i)) @ state^T
    c_scaled = Cm * jnp.exp(cs)[:, None]                          # (c, N)
    y_off = jax.lax.dot_general(c_scaled, state,
                                (((1,), (1,)), ((), ())))         # (c, P)

    # intra-chunk: att[i,j] = C_i·B_j · exp(cs_i - cs_j) · dt_j  (i >= j)
    att = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (c, c)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    w = jnp.where(rows >= cols, att * decay * dt[None, :], 0.0)
    y_diag = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))  # (c, P)

    y_ref[0, 0, 0] = (y_off + y_diag).astype(y_ref.dtype)

    # state update: state = state·exp(cs_last) + Σ_j exp(cs_last-cs_j)·dt_j·x_j⊗B_j
    seg = jnp.exp(cs[-1] - cs) * dt                               # (c,)
    xw = x * seg[:, None]                                         # (c, P)
    upd = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())))   # (P, N)
    state_ref[...] = state * jnp.exp(cs[-1]) + upd

    @pl.when(z == nz - 1)
    def _finalize():
        hlast_ref[0, 0] = state_ref[...]


def ssd_scan_bhzc(x, dt, cs, Bm, Cm, *, interpret: bool = False):
    """x: (B,H,nc,c,P); dt,cs: (B,H,nc,c); Bm,Cm: (B,nc,c,N).

    Returns (y: (B,H,nc,c,P) f32-accurate in x.dtype, h_last: (B,H,P,N) f32).
    """
    B, H, nc, c, P = x.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, chunk=c)
    grid = (B, H, nc)
    y, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, c, P), lambda b, h, z: (b, h, z, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, h, z: (b, h, z, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, h, z: (b, h, z, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, z: (b, z, 0, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, z: (b, z, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, c, P), lambda b, h, z: (b, h, z, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, z: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, c, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, cs, Bm, Cm)
    return y, hlast
