"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs as plain JAX ops for correctness validation; on TPU the same
``pallas_call`` lowers to Mosaic.  Model code calls these via
``cfg.attn_impl == "pallas"``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import rg_lru as _lru
from . import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, scale: float = 1.0,
                    block_q: int = 128, block_k: int = 128):
    """q: (B,Sq,H,Dh); k,v: (B,Sk,KV,Dh) -> (B,Sq,H,Dh)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def decode_attention(q, k, v, valid, *, softcap: Optional[float] = None,
                     scale: float = 1.0, block_k: int = 512):
    """q: (B,1,H,Dh); k,v: (B,L,KV,Dh); valid: (L,) or (B,L) -> (B,1,H,Dh)."""
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (q.shape[0], valid.shape[0]))
    qt = q.transpose(0, 2, 1, 3)                     # (B,H,1,Dh)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _dec.decode_attention_bhd(qt, kt, vt, valid, scale=scale,
                                    softcap=softcap, block_k=block_k,
                                    interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def ssd_scan(xc, dtc, dA, cs, Bc, Cc, h0=None):
    """Adapter matching ``repro.models.ssm.ssd_chunked``'s kernel call.

    xc: (B,nc,c,H,P); dtc/cs: (B,nc,c,H); Bc,Cc: (B,nc,c,N).
    Returns (y: (B, L, H, P), h_last: (B,H,P,N)).
    """
    assert h0 is None, "prefill state chaining uses the jnp path"
    B, nc, c, H, P = xc.shape
    x = xc.transpose(0, 3, 1, 2, 4)                  # (B,H,nc,c,P)
    # fold dt into the kernel inputs: kernel consumes dt & cs per (b,h,z)
    dt = dtc.transpose(0, 3, 1, 2)                   # (B,H,nc,c)
    cseq = cs.transpose(0, 3, 1, 2)                  # (B,H,nc,c)
    y, hlast = _ssd.ssd_scan_bhzc(x, dt, cseq, Bc, Cc,
                                  interpret=_interpret())
    L = nc * c
    yout = y.transpose(0, 2, 3, 1, 4).reshape(B, L, H, P)
    return yout, hlast


def rg_lru(a, x, h0=None, *, block_w: int = 512, block_s: int = 128):
    """a, x: (B,S,W) f32 -> hidden trajectory (B,S,W) f32."""
    if h0 is not None:
        x = x.at[:, 0, :].add(a[:, 0, :] * h0)
    return _lru.rg_lru_bsw(a, x, block_w=block_w, block_s=block_s,
                           interpret=_interpret())
