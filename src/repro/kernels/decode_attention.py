"""Flash-decode (TPU Pallas): one query token against a long KV cache.

Decode attention is pure HBM bandwidth: the kernel streams KV blocks through
VMEM once, keeping the online-softmax state (m, l, acc) in scratch.  The
``valid`` mask handles both full caches (slots ≤ pos) and ring buffers
(sliding-window slot validity) — masking is computed on the host side once
per step and streamed as an i32 vector.

Grid (B, H, L/bk), KV block innermost; GQA via ``h // groups`` index map.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float,
                   softcap: Optional[float]):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, d)
    ok = valid_ref[0] != 0                                 # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(ok[None, :], jnp.exp(s - m_cur[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_cur

    @pl.when(jk == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def decode_attention_bhd(q, k, v, valid, *, scale: float,
                         softcap: Optional[float] = None,
                         block_k: int = 512, interpret: bool = False):
    """q: (B,H,1,Dh); k,v: (B,KV,L,Dh); valid: (B,L) bool -> (B,H,1,Dh)."""
    B, H, _, Dh = q.shape
    KV, L = k.shape[1], k.shape[2]
    g = H // KV
    bk = min(block_k, L)
    assert L % bk == 0, (L, bk)
    grid = (B, H, L // bk)

    kernel = functools.partial(_decode_kernel, scale=scale, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, Dh), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid.astype(jnp.int32))
