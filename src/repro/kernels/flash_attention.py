"""Flash attention (TPU Pallas): tiled online-softmax, O(S) memory.

Grid (B, H, Sq/bq, Sk/bk) — the K dim is innermost so the running
(max, denom, accumulator) state lives in VMEM scratch across K blocks and the
output tile is written once at the last K block.  GQA reads the KV head via
``h // groups`` in the BlockSpec index map (no repeated-KV materialisation).
Causal/sliding-window masks skip fully-masked K blocks (predicated compute).

Block sizes default to (128, 128): MXU-aligned in the lane dim and a
(bq + 2·bk) × Dh ≤ 128·4·256·4B ≈ 0.5 MB VMEM working set at Dh=256.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  seq_k: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = jk * block_k
    rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_cur

    @pl.when(jk == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, window: Optional[int],
                         softcap: Optional[float], scale: float,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (B,H,Sq,Dh); k,v: (B,KV,Sk,Dh) -> (B,H,Sq,Dh)."""
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    grid = (B, H, Sq // bq, Sk // bk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
