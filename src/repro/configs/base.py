"""Config system: architecture + run-shape + mesh configs.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
the four canonical input shapes live here.  ``reduced()`` derives the small
CPU-smoke-test variant of any architecture (same family/wiring, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // num_heads

    # attention flavour
    block_pattern: Tuple[str, ...] = ("global",)   # cycled over layers
    window_size: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    max_seq_len: int = 131_072

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2) / RG-LRU (recurrentgemma)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    lru_width: int = 0

    # encoder-decoder
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # modality frontend (STUB: precomputed embeddings arrive as inputs)
    frontend: Optional[str] = None    # None | "vision" | "audio"
    num_prefix_tokens: int = 0        # e.g. 256 SigLIP patch embeddings

    act: str = "silu"                 # silu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # implementation switches (perf levers; see EXPERIMENTS.md §Perf)
    attn_impl: str = "blocked"        # blocked | einsum | pallas
    remat: str = "none"               # none | full | selective
    scan_layers: bool = True
    moe_impl: str = "onehot"          # onehot (GShard dispatch) | sort (gather)
    moe_group_size: int = 2048        # routing-group tokens (onehot path)
    kv_cache_dtype: str = "model"     # model (= dtype) | int8 (quantised KV)
    attn_scores_f32: bool = True      # False: bf16 score tensors (halves the
                                      # blocked-attention HBM term)
    pipeline_stages: int = 1          # >1: GPipe over the `pod` mesh axis
    pipeline_microbatches: int = 8

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        cleanly over the 16-way model axis (Megatron-style padding).  Padded
        logit columns are masked to -inf before softmax/CE."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def attention_free(self) -> bool:
        return all(b in ("mamba2",) for b in self.block_pattern)

    @property
    def full_attention(self) -> bool:
        """True if any layer uses unbounded global attention."""
        return any(b == "global" for b in self.block_pattern) or \
            self.is_encoder_decoder

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable?, reason) for an (arch × shape) cell — see DESIGN.md §5."""
    if shape.name == "long_500k" and model.full_attention:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (one fwd/train step)."""
    pattern_len = len(cfg.block_pattern)
    layers = max(2 * pattern_len, 2)
    enc = min(cfg.num_encoder_layers, 2) if cfg.is_encoder_decoder else 0
    return cfg.replace(
        num_layers=layers,
        num_encoder_layers=enc,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        moe_d_ff=64 if cfg.num_experts else 0,
        num_experts=min(cfg.num_experts, 8),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        vocab_size=256,
        window_size=32,
        max_seq_len=512,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=16,
        lru_width=64 if cfg.lru_width else 0,
        num_prefix_tokens=8 if cfg.num_prefix_tokens else 0,
        dtype="float32",
    )
