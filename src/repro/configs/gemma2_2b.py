"""Gemma2-2B [arXiv:2408.00118]: local/global alternating attention with
logit softcaps, 26L, d_model 2304, 8 heads GQA kv=4, d_ff 9216, vocab 256k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    block_pattern=("local", "global"),     # 1:1 alternation
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
