"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64 routed
top-6 experts, 28L, d_model 2048, 16 heads (kv=16), expert d_ff 1408."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                 # per-expert width (fine-grained)
    moe_d_ff=1408,
    vocab_size=102_400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    block_pattern=("global",),
    rope_theta=10_000.0,
    tie_embeddings=False,
)
