"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU recurrent blocks
with 1 local-attention layer per 2 recurrent layers, 26L, d_model 2560,
10 heads MQA kv=1, d_ff 7680.  Attention is bounded-window only => runs the
long_500k cell (constant-size state)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,                       # pattern cycles rglru,rglru,local
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    lru_width=2560,
    conv_width=4,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
