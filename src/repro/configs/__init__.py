"""Architecture config registry: ``get_config("<arch-id>")`` + shapes."""
from .base import (ModelConfig, ShapeConfig, SHAPES, cell_is_runnable,
                   reduced)
from . import (dbrx_132b, deepseek_moe_16b, gemma2_2b, granite_3_8b,
               mamba2_130m, mistral_nemo_12b, paligemma_3b,
               recurrentgemma_2b, seamless_m4t_large_v2, starcoder2_7b)

ARCHITECTURES = {
    m.CONFIG.name: m.CONFIG
    for m in (deepseek_moe_16b, dbrx_132b, granite_3_8b, gemma2_2b,
              starcoder2_7b, mistral_nemo_12b, recurrentgemma_2b,
              mamba2_130m, paligemma_3b, seamless_m4t_large_v2)
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHITECTURES)}")


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHITECTURES",
           "get_config", "get_shape", "cell_is_runnable", "reduced"]
