"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407]: dense GQA kv=8,
40L, d_model 5120, 32 heads (head_dim 128), d_ff 14336, 128k context."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    block_pattern=("global",),
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    tie_embeddings=False,
)
