"""SeamlessM4T-Large-v2 [arXiv:2308.11596]: encoder-decoder transformer
backbone (audio frontend STUB: precomputed frame embeddings), 24L enc +
24L dec, d_model 1024, 16 heads (kv=16), d_ff 8192, vocab 256206."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,                 # decoder layers
    num_encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    block_pattern=("global",),
    frontend="audio",
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
