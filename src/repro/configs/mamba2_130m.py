"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD (state-space duality),
24L, d_model 768, state 128, expand 2, head_dim 64.  Runs long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,                # unused by mamba blocks
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,                     # no MLP: mamba block carries the capacity
    vocab_size=50_280,
    block_pattern=("mamba2",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_width=4,
    tie_embeddings=True,
)
