"""StarCoder2-7B [arXiv:2402.19173]: dense GQA kv=4 decoder w/ RoPE,
32L, d_model 4608, 36 heads, d_ff 18432."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18_432,
    vocab_size=49_152,
    block_pattern=("global",),
    act="gelu",
    rope_theta=100_000.0,
    tie_embeddings=True,
)
