"""PaliGemma-3B [arXiv:2407.07726]: SigLIP vision frontend (STUB: precomputed
patch embeddings) + Gemma-2B text backbone: 18L, d_model 2048, 8 heads MQA
kv=1, d_ff 16384, vocab 257216, 256 image-prefix tokens."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    block_pattern=("global",),
    frontend="vision",
    num_prefix_tokens=256,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
