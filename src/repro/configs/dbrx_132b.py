"""DBRX-132B [hf:databricks/dbrx-base]: 16-expert top-4 MoE, 40L,
d_model 6144, 48 heads GQA kv=8, expert d_ff 10752."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10_752,
    moe_d_ff=10_752,
    vocab_size=100_352,
    num_experts=16,
    num_shared_experts=0,
    top_k=4,
    block_pattern=("global",),
    rope_theta=500_000.0,
    tie_embeddings=False,
)
