"""Granite-3-8B [hf:ibm-granite]: dense GQA decoder, 40L, d_model 4096,
32 heads kv=8, d_ff 12800."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12_800,
    vocab_size=49_155,
    block_pattern=("global",),
    rope_theta=10_000.0,
    tie_embeddings=True,
)
