"""Fail-stop fault specification + batched fault plans (DESIGN.md §14).

:class:`FaultSpec` is the declarative form of one fail-stop event: PE
``pe_id`` dies permanently at ``fail_time_us``.  Like every scenario
sub-spec (§9) it is frozen, hashable and registered as an all-metadata JAX
pytree, so a ``Scenario`` carrying faults still flattens to zero array
leaves and keys the table cache.

The kernels consume faults in two forms:

* the reference kernel takes the ``(pe_id, fail_time_us)`` pairs directly
  (last one wins per PE, matching its historical dict semantics);
* the JAX kernel takes a dense **fault plan** — a ``(P,)`` float32 vector of
  fail times, ``+inf`` meaning "never fails" — which vmaps into stacked
  ``(F, P)`` lane plans for ``sweep(axes={"faults": [...]})``.

``fail_time_us`` is quantised to float32 at construction so the reference
kernel's python-float comparisons and the JAX kernel's f32 comparisons
agree bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Iterable, Optional, Sequence, Tuple

import jax
import numpy as np

from .errors import ScenarioError

FAULT_KINDS = ("fail_stop",)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fail-stop event: PE ``pe_id`` dies permanently at ``fail_time_us``.

    Tasks in flight or queued on the PE at that moment (and their already
    committed descendants) are rolled back and re-scheduled on the surviving
    PEs; ``fail_time_us=inf`` never fires (a no-op).  ``kind`` is reserved
    for future fault models; only ``"fail_stop"`` exists today.
    """
    pe_id: int
    fail_time_us: float
    kind: str = "fail_stop"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ScenarioError(
                f"unknown fault kind {self.kind!r}; have {FAULT_KINDS}")
        pe = int(self.pe_id)
        if pe < 0:
            raise ScenarioError(f"fault pe_id must be >= 0, got {pe}")
        t = float(np.float32(self.fail_time_us))
        if np.isnan(t):
            raise ScenarioError("fault fail_time_us must not be NaN")
        object.__setattr__(self, "pe_id", pe)
        object.__setattr__(self, "fail_time_us", t)

    @property
    def is_noop(self) -> bool:
        """True when the event can never fire (infinite fail time)."""
        return not np.isfinite(self.fail_time_us)


jax.tree_util.register_dataclass(
    FaultSpec, data_fields=[], meta_fields=["pe_id", "fail_time_us", "kind"])


def normalize_failures(failures) -> Tuple[FaultSpec, ...]:
    """Canonicalise a failures field to a tuple of :class:`FaultSpec`.

    Accepts the pre-FaultSpec bare ``(pe_id, fail_time_us)`` pairs through a
    one-release ``DeprecationWarning`` shim (the §9 ``*_mj`` playbook).
    """
    if failures is None:
        return ()
    out = []
    warned = False
    for f in failures:
        if isinstance(f, FaultSpec):
            out.append(f)
            continue
        pe_id, fail_time_us = f            # legacy (pe_id, fail_time_us)
        if not warned:
            warnings.warn(
                "bare (pe_id, fail_time_us) failure tuples are deprecated; "
                "pass repro.scenario.FaultSpec(pe_id=..., fail_time_us=...) "
                "(this shim lasts one release)",
                DeprecationWarning, stacklevel=3)
            warned = True
        out.append(FaultSpec(pe_id=pe_id, fail_time_us=fail_time_us))
    return tuple(out)


def ref_failures(failures: Sequence[FaultSpec]
                 ) -> Optional[Sequence[Tuple[int, float]]]:
    """The ``(pe_id, fail_time_us)`` pair list the reference kernel takes
    (``None`` when nothing can fire, keeping its fault-free fast path)."""
    pairs = [(f.pe_id, f.fail_time_us) for f in normalize_failures(failures)
             if not f.is_noop]
    return pairs or None


def fault_plan(failures: Sequence[FaultSpec], num_pes: int,
               width: Optional[int] = None) -> Optional[np.ndarray]:
    """The dense ``(P,)`` f32 fail-time plan the JAX kernel consumes.

    ``+inf`` marks PEs that never fail; duplicate ``pe_id`` entries resolve
    last-wins (the reference kernel's dict semantics).  ``pe_id`` validates
    against ``num_pes`` (the narrowest real design the plan must apply to);
    ``width`` (default ``num_pes``) sets the vector length — the padded PE
    width of a stacked design batch.  Returns ``None`` when no event can
    ever fire — empty specs and all-``inf`` specs normalise to the
    fault-free fast path, never changing the compiled program (the §14
    no-op contract).
    """
    plan = np.full(width or num_pes, np.inf, np.float32)
    fired = False
    for f in normalize_failures(failures):
        if f.pe_id >= num_pes:
            raise ScenarioError(
                f"fault pe_id={f.pe_id} out of range for a {num_pes}-PE "
                f"design (valid ids: 0..{num_pes - 1})")
        plan[f.pe_id] = np.float32(f.fail_time_us)
        fired = fired or not f.is_noop
    return plan if fired else None


def stack_fault_plans(fault_sets: Sequence[Sequence[FaultSpec]],
                      num_pes: int, width: Optional[int] = None
                      ) -> Tuple[Optional[np.ndarray], int]:
    """Stacked ``(F, P)`` lane plans for a ``faults`` sweep axis.

    Returns ``(plans, max_faults)`` where ``max_faults`` is the widest
    finite-fault count across lanes (it bounds the extra scan iterations
    every lane must carry — the scan length is static).  ``plans`` is
    ``None`` when every lane is a no-op: the sweep then routes through the
    exact fault-free program and tiles the results.
    """
    width = width or num_pes
    rows = [fault_plan(fs, num_pes, width) for fs in fault_sets]
    if all(r is None for r in rows):
        return None, 0
    plans = np.stack([np.full(width, np.inf, np.float32) if r is None
                      else r for r in rows])
    max_faults = int(np.isfinite(plans).sum(axis=1).max())
    return plans, max_faults


def fault_scan_steps(num_jobs: int, t_max: int, max_faults: int) -> int:
    """Static epoch-scan length under ``max_faults`` fail-stop events.

    Each fault can roll back every committed task (≤ J·T re-commits) and
    costs at most one skipped epoch, so ``J·T·(1 + F) + F`` iterations
    always suffice (DESIGN.md §14)."""
    return num_jobs * t_max * (1 + max_faults) + max_faults


def pe_loss_faults(pe_ids: Iterable[int], fail_time_us: float = 0.0,
                   k: int = 1) -> Tuple[Tuple[FaultSpec, ...], ...]:
    """Every k-subset of ``pe_ids`` failing at ``fail_time_us`` — the
    degraded-mode lane axis ``dse.evaluate(faults=...)`` ranks designs
    under (k-PE-loss resilience, DESIGN.md §14)."""
    return tuple(
        tuple(FaultSpec(pe_id=p, fail_time_us=fail_time_us) for p in combo)
        for combo in itertools.combinations(sorted(set(int(p) for p in pe_ids)), k))
