"""repro.scenario — the unified, declarative entry point.

One frozen :class:`Scenario` names the SoC design, application mix, workload
trace, scheduler policy, DVFS governor, thermal settings and failure
injection; two verbs consume it:

    run(scenario, backend="ref"|"jax")   one simulation, one Result surface
    sweep(scenario, axes={...})          cross-product batches in one
                                         vmapped/jitted tensor program

Both are bit-for-bit delegates to the legacy kernels (`repro.core.simulate`,
`build_tables` + `simulate_jax`, `repro.dse` batching) — see DESIGN.md §9
for the pytree layout, padding rules and equivalence contract.
"""
from .config import Scenario, ThermalSpec, TraceSpec
from .errors import BackendCapabilityError, LaneAxisError, ScenarioError
from .faults import FaultSpec, pe_loss_faults
from .result import Result, SweepResult
from .run import run, tables_for
from .sweep import sweep

__all__ = ["Scenario", "ThermalSpec", "TraceSpec", "FaultSpec",
           "pe_loss_faults", "Result", "SweepResult", "run", "sweep",
           "tables_for", "ScenarioError", "BackendCapabilityError",
           "LaneAxisError"]
