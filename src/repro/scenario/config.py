"""Declarative scenario configuration — one frozen dataclass wires a run.

A :class:`Scenario` names everything a simulation needs — the SoC design
point, the application mix, the workload trace, the scheduler policy, the
DVFS governor, the thermal-evaluation settings and optional fail-stop
events — without materialising any of it.  Materialisation (``soc()``,
``applications()``, ``job_trace()``, ``make_scheduler()``…) happens in
exactly one place, so every driver (benchmarks, examples, DSE, tests)
constructs work the same way.

``Scenario`` and its sub-specs are frozen, hashable, and registered as JAX
pytrees whose fields are all static metadata: a scenario can ride through
``jit``/``vmap`` closures and serve as a cache key (see
``repro.scenario.run._cached_tables``).  See DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple, Union

import jax

from ..core.applications import Application, get_application
from ..core.dvfs import Governor, GovernorPolicy, get_governor
from ..core.jobgen import JobTrace, deterministic_trace, poisson_trace
from ..core.resources import ResourceDB
from ..core.schedulers import (Scheduler, TableScheduler, get_scheduler,
                               solve_optimal_table)
from ..dse.space import DesignPoint
from .faults import FaultSpec, normalize_failures


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Declarative workload: which jobs arrive when (materialised lazily).

    ``kind="poisson"`` draws exponential inter-arrival gaps at
    ``rate_jobs_per_ms`` (paper Fig. 3 x-axis); ``kind="deterministic"``
    spaces jobs ``gap_us`` apart.  ``mix`` optionally weights the choice
    among the scenario's applications.
    """
    kind: str = "poisson"                      # "poisson" | "deterministic"
    rate_jobs_per_ms: float = 20.0
    gap_us: float = 50.0                       # deterministic arrivals only
    num_jobs: int = 100
    mix: Optional[Tuple[float, ...]] = None
    seed: int = 0

    def materialize(self, app_names: Tuple[str, ...]) -> JobTrace:
        if self.kind == "poisson":
            return poisson_trace(self.rate_jobs_per_ms, self.num_jobs,
                                 app_names, seed=self.seed, mix=self.mix)
        if self.kind == "deterministic":
            return deterministic_trace(self.gap_us, self.num_jobs, app_names,
                                       seed=self.seed)
        raise ValueError(f"unknown trace kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class ThermalSpec:
    """RC thermal co-simulation settings (see DESIGN.md §6).

    Consulted by the *static*-governor jax path only (the post-hoc binned
    peak-temperature scan).  Dynamic (ondemand-family) scenarios integrate
    temperature inside the kernel's DVFS loop instead — their resolution is
    the governor's ``sample_window_us`` / ``thermal_dt_s`` (DESIGN.md §7),
    and ``bins``/``repeats`` have no effect.
    """
    bins: int = 32              # power-trace time bins per schedule
    repeats: int = 3            # periods scanned past the steady-state start


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative simulation configuration.

    Fields:
      design      — SoC design point (defaults to the paper's Table-2 SoC);
      apps        — application names (or ``Application`` objects) in the mix;
      trace       — workload spec (see :class:`TraceSpec`);
      scheduler   — ``"met" | "etf" | "table"`` (table = offline ILP solve);
      governor    — DVFS governor name (``repro.core.dvfs.GOVERNORS``) or
                    ``"design"`` for a userspace governor pinned to the
                    design point's per-cluster frequency caps; dynamic
                    governors (``ondemand``/``throttle``) run the closed
                    DTPM loop on either backend (DESIGN.md §7);
      governor_params — extra governor kwargs as a hashable (key, value)
                    tuple, e.g. ``(("up_threshold", 0.9),)``;
      thermal     — peak-temperature evaluation settings;
      failures    — fail-stop events (:class:`FaultSpec`, …), supported on
                    both backends (DESIGN.md §14); bare
                    ``(pe_id, fail_time_us)`` tuples are accepted through a
                    one-release ``DeprecationWarning`` shim;
      telemetry   — record per-sampling-window timelines (frequency,
                    utilisation, power, temperature) on ``Result.telemetry``
                    (DESIGN.md §11).  Observation-only: the simulated
                    schedule and its metrics are unchanged.
    """
    design: DesignPoint = DesignPoint()
    apps: Tuple[Union[str, Application], ...] = ("wifi_tx",)
    trace: TraceSpec = TraceSpec()
    scheduler: str = "etf"
    governor: str = "performance"
    governor_params: Tuple[Tuple[str, float], ...] = ()
    thermal: ThermalSpec = ThermalSpec()
    failures: Tuple[FaultSpec, ...] = ()
    telemetry: bool = False

    def __post_init__(self):
        # canonicalise the failures field (legacy bare tuples warn + convert)
        # so every consumer — table cache keys included — sees FaultSpecs
        object.__setattr__(self, "failures",
                           normalize_failures(self.failures))

    # -- materialisation (the single construction point) -------------------
    def soc(self) -> ResourceDB:
        """A fresh ``ResourceDB`` for the design point."""
        return self.design.to_db()

    def applications(self) -> Tuple[Application, ...]:
        return tuple(a if isinstance(a, Application) else get_application(a)
                     for a in self.apps)

    def app_names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.applications())

    def job_trace(self) -> JobTrace:
        return self.trace.materialize(self.app_names())

    def make_governor(self) -> Governor:
        if self.governor == "design":
            if self.governor_params:
                raise ValueError(
                    "governor='design' takes no governor_params (the design "
                    "point pins the frequency caps); name an explicit "
                    "governor to parameterise one")
            return self.design.governor()      # frequency-cap userspace
        gov = get_governor(self.governor, **dict(self.governor_params))
        if gov.policy().dynamic:
            # dynamic policies range over the design's hardware envelope:
            # the OPP ladder stops at the per-cluster frequency caps, on
            # both backends (capped_levels / build_tables(freq_caps=…))
            gov.freq_caps = self.design.freq_caps()
        return gov

    def make_policy(self) -> GovernorPolicy:
        """The governor's array-form per-window transition (DESIGN.md §7).

        ``policy.dynamic`` selects the kernel branch on the JAX backend:
        static governors bake one OPP into the tables, the ondemand family
        runs the closed DVFS + thermal loop inside the epoch scan.
        """
        return self.make_governor().policy()

    def schedule_table(self) -> Optional[Dict[Tuple[str, int], int]]:
        """The offline ILP table for ``scheduler="table"`` (cached), else None."""
        if self.scheduler != "table":
            return None
        return _solve_table_cached(self.design, self.apps)

    def make_scheduler(self) -> Scheduler:
        if self.scheduler == "table":
            return TableScheduler(self.schedule_table())
        return get_scheduler(self.scheduler)

    # -- convenience -------------------------------------------------------
    def replace(self, **kwargs) -> "Scenario":
        """``dataclasses.replace`` that also resolves dotted axis paths,
        e.g. ``replace(**{"trace.seed": 3, "design.num_big": 2})``."""
        out = self
        for key, value in kwargs.items():
            if "." in key:
                head, _, field = key.partition(".")
                sub = dataclasses.replace(getattr(out, head), **{field: value})
                out = dataclasses.replace(out, **{head: sub})
            else:
                out = dataclasses.replace(out, **{key: value})
        return out

    def at_rate(self, rate_jobs_per_ms: float) -> "Scenario":
        return self.replace(**{"trace.rate_jobs_per_ms": rate_jobs_per_ms})

    def with_seed(self, seed: int) -> "Scenario":
        return self.replace(**{"trace.seed": seed})

    def label(self) -> str:
        return (f"{self.design.label()}|{'+'.join(self.app_names())}"
                f"|{self.scheduler}|{self.governor}")


@functools.lru_cache(maxsize=64)
def _solve_table_cached(design: DesignPoint,
                        apps: Tuple[Union[str, Application], ...]):
    db = design.to_db()
    table: Dict[Tuple[str, int], int] = {}
    for app in (a if isinstance(a, Application) else get_application(a)
                for a in apps):
        table.update(solve_optimal_table(db, app))
    return table


# All fields are static metadata: flattening yields no array leaves, so a
# Scenario can close over jitted code or key a cache without retracing.
for _cls in (TraceSpec, ThermalSpec, Scenario):
    jax.tree_util.register_dataclass(
        _cls, data_fields=[],
        meta_fields=[f.name for f in dataclasses.fields(_cls)])
