"""Unified result surface over the reference kernel, the JAX kernel and
batched sweeps.

:class:`Result` is one simulation's metrics — latency, throughput, energy,
peak temperature, utilization — regardless of which backend produced it; the
backend-native output (``SimResult`` or the JAX output dict) stays reachable
via ``raw``.  :class:`SweepResult` is the batched counterpart: every metric
is an ndarray shaped like the sweep's axes cross-product.

Peak temperature is backend-specific by necessity: for static governors the
JAX backend runs the binned RC co-simulation (DESIGN.md §6) while the
reference backend reports the analytical steady state of the schedule's
realised per-node power split — both upper-bound views of the same lumped
network.  Dynamic (ondemand-family) scenarios on the JAX backend report the
peak of the kernel's *inline* RC loop instead — an ambient-start transient
at the governor's ``thermal_dt_s`` resolution (DESIGN.md §7); on
millisecond traces it stays near ambient unless ``thermal_dt_s`` dilates
thermal time, so compare it across policies, not across backends.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from ..core import thermal as _thermal
from ..core.power import EnergyReport
from ..core.resources import ResourceDB
from ..core.simkernel_ref import SimResult
from .config import Scenario


@dataclasses.dataclass
class Result:
    """Metrics of one simulated scenario (one metrics surface, any backend)."""
    scenario: Scenario
    backend: str                       # "ref" | "jax"
    avg_latency_us: float
    throughput_jobs_per_ms: float
    makespan_us: float
    energy_j: float
    avg_power_w: float
    peak_temp_c: float
    utilization: np.ndarray            # (num_pes,) busy / makespan
    raw: Any                           # SimResult (ref) | output dict (jax)
    telemetry: Optional[Any] = None    # obs.telemetry.Telemetry when recorded
    manifest: Optional[Dict] = None    # obs.metrics.run_manifest (DESIGN §11)

    @property
    def energy_report(self) -> Optional[EnergyReport]:
        return self.raw.energy if isinstance(self.raw, SimResult) else None

    @classmethod
    def from_ref(cls, scenario: Scenario, db: ResourceDB,
                 res: SimResult, telemetry=None) -> "Result":
        split = _thermal.node_power_split(db, res.energy.energy_per_pe_j,
                                          res.makespan_us)
        peak = float(_thermal.steady_state(split)[:3].max())
        return cls(scenario=scenario, backend="ref",
                   avg_latency_us=float(res.avg_job_latency_us),
                   throughput_jobs_per_ms=float(res.throughput_jobs_per_ms),
                   makespan_us=float(res.makespan_us),
                   energy_j=float(res.energy.total_energy_j),
                   avg_power_w=float(res.energy.avg_power_w),
                   peak_temp_c=peak,
                   utilization=res.pe_utilization(db), raw=res,
                   telemetry=telemetry)

    @classmethod
    def from_jax(cls, scenario: Scenario, out: Dict, num_pes: int,
                 peak_temp_c: float, telemetry=None) -> "Result":
        makespan = float(np.asarray(out["makespan_us"]))
        num_jobs = int(np.asarray(out["job_finish"]).shape[0])
        energy = float(np.asarray(out["energy_j"]))
        busy = np.asarray(out["busy_per_pe_us"], np.float64)[:num_pes]
        return cls(scenario=scenario, backend="jax",
                   avg_latency_us=float(np.asarray(out["avg_job_latency_us"])),
                   throughput_jobs_per_ms=num_jobs / max(makespan, 1e-9) * 1e3,
                   makespan_us=makespan, energy_j=energy,
                   avg_power_w=energy / max(makespan * 1e-6, 1e-12),
                   peak_temp_c=float(peak_temp_c),
                   utilization=busy / max(makespan, 1e-9), raw=out,
                   telemetry=telemetry)


@dataclasses.dataclass
class SweepResult:
    """Metrics of a ``sweep()``: one ndarray per metric, shaped like the
    cross-product of the sweep axes (in the axes-dict order)."""
    base: Scenario
    backend: str
    axes: Dict[str, Tuple]             # axis name -> swept values
    avg_latency_us: np.ndarray
    throughput_jobs_per_ms: np.ndarray
    makespan_us: np.ndarray
    energy_j: np.ndarray
    peak_temp_c: np.ndarray
    busy_per_pe_us: np.ndarray         # shape + (padded num_pes,)
    telemetry: Optional[np.ndarray] = None   # object array of Telemetry
                                             # (axes shape), when recorded

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(len(v) for v in self.axes.values())

    @property
    def num_points(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def utilization(self) -> np.ndarray:
        return self.busy_per_pe_us / np.maximum(
            self.makespan_us[..., None], 1e-9)

    def iter_records(self) -> Iterator[Tuple[Dict[str, Any], Dict[str, float]]]:
        """Yield (axis-coordinates, metrics) per sweep point, C order."""
        names = list(self.axes)
        for idx in np.ndindex(*self.shape):
            coords = {n: self.axes[n][i] for n, i in zip(names, idx)}
            yield coords, dict(
                avg_latency_us=float(self.avg_latency_us[idx]),
                throughput_jobs_per_ms=float(
                    self.throughput_jobs_per_ms[idx]),
                makespan_us=float(self.makespan_us[idx]),
                energy_j=float(self.energy_j[idx]),
                peak_temp_c=float(self.peak_temp_c[idx]))
