"""Sharded + chunked lane execution for sweep grids (DESIGN.md §13).

The sweep's compiled grid programs are embarrassingly parallel along their
leading *lane* axis — the stacked design axis of ``SimTables`` (static
sweeps) or, when the policy grid is the wide one, the stacked
:class:`~repro.core.dvfs.GovernorPolicy` axis (dynamic DTPM sweeps).  This
module scales that axis two ways, composably:

* **lane sharding** — the per-chunk lane tensors are placed with a
  ``NamedSharding`` over the 1-D lane mesh (``repro.sharding.lane_mesh``,
  all local devices) before entering the jitted grid program, so XLA's SPMD
  partitioner splits the vmapped lanes across devices.  Lanes are
  independent, so partitioning never changes per-lane numerics: sharded
  results are bit-for-bit equal to the single-device sweep.
* **chunked streaming** — lanes stream through ONE compiled program in
  fixed-shape chunks (``sweep(..., chunk=N)``): the stacked lane tensors
  stay host-resident (numpy leaves) and only one chunk is device-resident
  at a time, with the chunk's input buffers donated back to XLA, so peak
  device memory is O(chunk), not O(grid).

Both paths pad the lane count up to the chunk/device quantum by repeating
lane 0.  Unlike ``dse.batch``'s *in-kernel* inert padding (BIG latency,
zero power), pad lanes here are ordinary simulations whose outputs are
sliced off before assembly — inert by construction because lanes never
interact.  Chunk shapes are pinned (every chunk padded to the same width,
``pad_pes``-style), so chunking and uneven lane counts never add compiles:
one trace per (policy shape, chunk width).

Observability: ``scenario.shard.devices`` (lane-mesh width of the most
recent launch), ``scenario.shard.pad_lanes`` (inert lanes added) and
``scenario.sweep.chunks`` (chunks streamed) in the ``obs.metrics`` registry.
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dse.batch import _simulate_grid, _simulate_grid_faults
from ..dse.thermal_jax import peak_temperature_grid
from ..core.simkernel_jax import _simulate_dtpm
from ..obs import metrics as _metrics
from ..sharding import lane_count, lane_mesh, lane_sharding

# lane-mesh width of the most recent sharded launch (1 = unsharded)
shard_devices = _metrics.counter("scenario.shard.devices")
# cumulative inert pad lanes added for chunk/device-count divisibility
shard_pad_lanes = _metrics.counter("scenario.shard.pad_lanes")
# cumulative fixed-shape chunks streamed through the grid programs
sweep_chunks = _metrics.counter("scenario.sweep.chunks")
# the sweep's one-program-per-policy-shape trace counter (same registry
# entry as ``sweep.compile_count``; looked up here, not in the jitted
# bodies, so the registry is never touched under trace)
_compile_count = _metrics.counter("scenario.sweep.compile_count")


def host_tree(tree):
    """The pytree with every array leaf as host-resident numpy (the form the
    chunked streamer slices from, keeping device residency O(chunk))."""
    return jax.tree_util.tree_map(np.asarray, tree)


def padded_width(lanes: int, chunk: Optional[int], quantum: int) -> int:
    """The pinned per-chunk lane width: ``chunk`` (or all lanes) rounded up
    to the device-count quantum.  Fixed across chunks and across grids of
    different lane counts (when ``chunk`` is given), so the jit cache sees
    one shape."""
    base = lanes if chunk is None else chunk
    return -(-base // quantum) * quantum


def pad_lane_axis(tree, lanes: int, width: int, axis: int = 0):
    """Pad every leaf's lane ``axis`` from ``lanes`` up to ``width`` by
    repeating lane 0 — pad lanes are real, independent simulations whose
    outputs are dropped, so padding is inert by construction."""
    if lanes == width:
        return tree

    def _pad(x):
        reps = np.take(x, np.zeros(width - lanes, np.intp), axis=axis)
        return np.concatenate([np.asarray(x), reps], axis=axis)

    return jax.tree_util.tree_map(_pad, tree)


def _device_put_lanes(tree, mesh):
    """Place a chunk's lane tensors: sharded over the lane mesh when one is
    installed, default single-device placement otherwise."""
    if mesh is None:
        return jax.tree_util.tree_map(jnp.asarray, tree)
    sharding = lane_sharding(mesh)
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(jnp.asarray(x), sharding), tree)


def _slice_lanes(tree, lo: int, hi: int, axis: int = 0):
    return jax.tree_util.tree_map(
        lambda x: x[(slice(None),) * axis + (slice(lo, hi),)], tree)


# --------------------------------------------------------------------------
# The jitted chunk programs — one trace per (policy shape, chunk width).
# The lane-chunk arguments are donated: each chunk's buffers are freshly
# device_put by the streamer, so XLA may reuse them for the outputs and the
# previous chunk never outlives its step.
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("policy", "num_jobs", "bins", "repeats"),
                   donate_argnames=("tables", "node_of_pe"))
def _chunk_static(tables, node_of_pe, arrival, app_idx, policy, num_jobs,
                  bins, repeats):
    """Static-governor chunk: schedule simulation + RC thermal scan for the
    (Dc, S) lane chunk — same fused body as ``sweep._sweep_grid``."""
    _compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    out = _simulate_grid(tables, policy, num_jobs, arrival, app_idx)
    temps = peak_temperature_grid(out, node_of_pe, tables.power_active,
                                  tables.power_idle, bins=bins,
                                  repeats=repeats)
    return out, temps


def _dtpm_grid(tables, gov, arrival, app_idx, policy, num_jobs):
    _compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    per_trace = jax.vmap(
        lambda tb, g, a, i: _simulate_dtpm(tb, policy, num_jobs, a, i, g),
        in_axes=(None, None, 0, 0))
    per_policy = jax.vmap(per_trace, in_axes=(None, 0, None, None))
    per_design = jax.vmap(per_policy, in_axes=(0, None, None, None))
    return per_design(tables, gov, arrival, app_idx)


# Two donation variants of the same DTPM grid: only the streamed lane
# argument is freshly allocated per chunk (the other is reused across
# chunks and must not be donated).
_chunk_dtpm_design = functools.partial(
    jax.jit, static_argnames=("policy", "num_jobs"),
    donate_argnames=("tables",))(_dtpm_grid)
_chunk_dtpm_policy = functools.partial(
    jax.jit, static_argnames=("policy", "num_jobs"),
    donate_argnames=("gov",))(_dtpm_grid)


@functools.partial(jax.jit,
                   static_argnames=("policy", "num_jobs", "bins", "repeats",
                                    "scan_steps"),
                   donate_argnames=("tables", "node_of_pe"))
def _chunk_static_faults(tables, node_of_pe, fplans, arrival, app_idx,
                         policy, num_jobs, bins, repeats, scan_steps):
    """Fail-stop static chunk: (F, Dc, S) lanes — same fused body as
    ``sweep._sweep_grid_faults``.  ``fplans`` is NOT donated: the (F, P)
    plan stack is reused by every chunk (DESIGN.md §14)."""
    _compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    out = _simulate_grid_faults(tables, policy, num_jobs, arrival, app_idx,
                                fplans, scan_steps)
    temps = jax.vmap(lambda o: peak_temperature_grid(
        o, node_of_pe, tables.power_active, tables.power_idle, bins=bins,
        repeats=repeats))(out)
    return out, temps


def _dtpm_grid_faults(tables, gov, fplans, arrival, app_idx, policy,
                      num_jobs, scan_steps):
    _compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    per_trace = jax.vmap(
        lambda tb, g, a, i, fp: _simulate_dtpm(tb, policy, num_jobs, a, i, g,
                                               fp, scan_steps=scan_steps),
        in_axes=(None, None, 0, 0, None))
    per_policy = jax.vmap(per_trace, in_axes=(None, 0, None, None, None))
    per_design = jax.vmap(per_policy, in_axes=(0, None, None, None, None))
    per_fault = jax.vmap(per_design, in_axes=(None, None, None, None, 0))
    return per_fault(tables, gov, arrival, app_idx, fplans)


# ``fplans`` never donates (reused across chunks), so the faulted DTPM grid
# keeps the same two lane-donation variants as the fault-free one.
_chunk_dtpm_design_faults = functools.partial(
    jax.jit, static_argnames=("policy", "num_jobs", "scan_steps"),
    donate_argnames=("tables",))(_dtpm_grid_faults)
_chunk_dtpm_policy_faults = functools.partial(
    jax.jit, static_argnames=("policy", "num_jobs", "scan_steps"),
    donate_argnames=("gov",))(_dtpm_grid_faults)


# --------------------------------------------------------------------------
# The streamer
# --------------------------------------------------------------------------

def _stream(lane_tree, lanes: int, chunk: Optional[int], mesh,
            launch) -> list:
    """Stream ``lane_tree`` (host numpy leaves, lane axis leading) through
    ``launch(device_chunk)`` in fixed-width chunks; returns the per-chunk
    results with pad lanes still attached (callers slice after concat)."""
    quantum = lane_count(mesh)
    width = padded_width(lanes, chunk, quantum)
    shard_devices.reset()
    shard_devices.inc(quantum)
    outs = []
    for lo in range(0, lanes, width):
        hi = min(lo + width, lanes)
        piece = _slice_lanes(lane_tree, lo, hi)
        if hi - lo < width:
            shard_pad_lanes.inc(width - (hi - lo))
            piece = pad_lane_axis(piece, hi - lo, width)
        sweep_chunks.inc()
        with warnings.catch_warnings():
            # the CPU backend cannot alias donated buffers and warns per
            # launch; donation is the accelerator story, the warning is noise
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            outs.append(launch(_device_put_lanes(piece, mesh)))
    return outs


def _concat_out(chunks: list, lanes: int, axis: int = 0) -> Dict:
    """Concatenate per-chunk output dicts on the lane axis and drop the pad
    lanes (host-side: chunk outputs leave the device as they arrive)."""
    keys = chunks[0].keys()
    out = {k: np.concatenate([np.asarray(c[k]) for c in chunks], axis=axis)
           for k in keys}
    sl = (slice(None),) * axis + (slice(0, lanes),)
    return {k: v[sl] for k, v in out.items()}


def run_static_grid(tables, node_of_pe, arrival, app_idx, *, policy: str,
                    num_jobs: int, bins: int, repeats: int,
                    chunk: Optional[int] = None, mesh=None,
                    fplans=None,
                    scan_steps: Optional[int] = None
                    ) -> Tuple[Dict, np.ndarray]:
    """The sharded/chunked twin of ``sweep._sweep_grid``: (D, S) lanes with
    the design axis streamed/sharded; returns host-resident outputs with
    exactly D lanes (bit-for-bit equal to the unsharded grid).

    ``fplans``/``scan_steps`` switch to the fail-stop grid: outputs gain a
    leading (F,) fault-lane axis, and the design axis (still the streamed
    one) moves to position 1 — the fault axis is outermost precisely so
    streaming stays a design-axis slice (DESIGN.md §14)."""
    lanes = int(np.asarray(tables.exec_us).shape[0])
    lane_tree = (host_tree(tables), host_tree(node_of_pe))
    faulted = fplans is not None
    fdev = jnp.asarray(fplans, jnp.float32) if faulted else None

    def launch(piece):
        tb, nodes = piece
        if faulted:
            out, temps = _chunk_static_faults(
                tb, nodes, fdev, arrival, app_idx, policy=policy,
                num_jobs=num_jobs, bins=bins, repeats=repeats,
                scan_steps=scan_steps)
        else:
            out, temps = _chunk_static(tb, nodes, arrival, app_idx,
                                       policy=policy, num_jobs=num_jobs,
                                       bins=bins, repeats=repeats)
        out = dict(out)
        out["_peak_temp_scan_c"] = temps
        return out

    out = _concat_out(_stream(lane_tree, lanes, chunk, mesh, launch), lanes,
                      axis=1 if faulted else 0)
    return out, out.pop("_peak_temp_scan_c")


def run_dtpm_grid(tables, gov, arrival, app_idx, *, policy: str,
                  num_jobs: int, chunk: Optional[int] = None, mesh=None,
                  fplans=None, scan_steps: Optional[int] = None) -> Dict:
    """The sharded/chunked twin of ``sweep._sweep_grid_dtpm``: (D, G, S)
    lanes, streaming/sharding whichever of the design (D) and policy (G)
    axes is wider — the GovernorPolicy leaves are as much a lane stack as
    the SimTables leaves (DESIGN.md §10).  ``fplans``/``scan_steps`` switch
    to the fail-stop grid: outputs gain a leading (F,) fault-lane axis and
    the streamed axis shifts one position right (DESIGN.md §14)."""
    D = int(np.asarray(tables.exec_us).shape[0])
    G = int(np.asarray(gov.up_threshold).shape[0])
    tables_h, gov_h = host_tree(tables), host_tree(gov)
    faulted = fplans is not None
    fdev = jnp.asarray(fplans, jnp.float32) if faulted else None
    if D >= G:                               # stream designs, reuse policies
        gov_dev = jax.tree_util.tree_map(jnp.asarray, gov_h)

        def launch(tb):
            if faulted:
                return _chunk_dtpm_design_faults(
                    tb, gov_dev, fdev, arrival, app_idx, policy=policy,
                    num_jobs=num_jobs, scan_steps=scan_steps)
            return _chunk_dtpm_design(tb, gov_dev, arrival, app_idx,
                                      policy=policy, num_jobs=num_jobs)

        return _concat_out(_stream(tables_h, D, chunk, mesh, launch), D,
                           axis=1 if faulted else 0)
    tables_dev = jax.tree_util.tree_map(jnp.asarray, tables_h)

    def launch(g):
        if faulted:
            return _chunk_dtpm_policy_faults(
                tables_dev, g, fdev, arrival, app_idx, policy=policy,
                num_jobs=num_jobs, scan_steps=scan_steps)
        return _chunk_dtpm_policy(tables_dev, g, arrival, app_idx,
                                  policy=policy, num_jobs=num_jobs)

    return _concat_out(_stream(gov_h, G, chunk, mesh, launch), G,
                       axis=2 if faulted else 1)


def resolve_mesh(shard: Optional[bool], devices=None):
    """The lane mesh a sweep should use: ``shard=None`` auto-shards when
    more than one local device is present, ``False`` never shards, ``True``
    asks for the mesh explicitly (still ``None`` — unsharded — when only
    one device exists; the chunked path works either way)."""
    if shard is False:
        return None
    return lane_mesh(devices)
