"""``run(scenario, backend=...)`` — one scenario, either kernel.

The facade is a thin, bit-for-bit delegate: ``backend="ref"`` materialises
the scenario and calls the event-heap oracle exactly as
``repro.core.simulate`` always has; ``backend="jax"`` builds the same
``SimTables`` the legacy ``build_tables`` + ``simulate_jax`` pair would and
runs the unchanged kernel (the equivalence contract is tested in
``tests/test_scenario.py``).  Tables are cached on the (frozen, hashable)
scenario minus its trace, so repeated runs over different workloads reuse
the compiled program and device constants.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import numpy as np

from ..core import simkernel_jax as _jaxk
from ..core import simkernel_ref as _refk
from ..core.simkernel_jax import SimTables
from ..core.thermal import cluster_nodes
from ..dse import thermal_jax as _thermal_jax
from ..obs import metrics as _metrics
from ..obs import telemetry as _obs_tel
from . import faults as _faults
from .config import Scenario, ThermalSpec, TraceSpec
from .errors import BackendCapabilityError, ScenarioError
from .result import Result

BACKENDS = ("ref", "jax")


def _tables_key(scn: Scenario) -> Scenario:
    """Strip table-irrelevant fields so different workloads share tables.

    The scheduler only shapes tables through the offline ILP table, so all
    non-"table" policies collapse to one cache entry per design/governor.
    Dynamic (ondemand-family) governors collapse further: their OPP ladders
    depend on the design and applications alone, so every policy
    parameterisation shares one table set.
    """
    scheduler = scn.scheduler if scn.scheduler == "table" else "etf"
    key = dataclasses.replace(scn, trace=TraceSpec(), failures=(),
                              thermal=ThermalSpec(), scheduler=scheduler,
                              telemetry=False)
    if key.make_policy().dynamic:
        key = dataclasses.replace(key, governor="ondemand",
                                  governor_params=())
    return key


@functools.lru_cache(maxsize=256)
def _cached_tables(key: Scenario, pad_pes: Optional[int]) -> SimTables:
    db = key.soc()
    return _jaxk.build_tables(db, key.applications(),
                              governor=key.make_governor(),
                              table=key.schedule_table(), pad_pes=pad_pes)


@functools.lru_cache(maxsize=256)
def _cached_tables_host(key: Scenario, pad_pes: Optional[int]) -> SimTables:
    """Host-resident (numpy-leaf) twin of :func:`_cached_tables`: built
    fresh (not via the device cache) so only one design's device arrays are
    ever live during construction — the chunked sweep's streaming source."""
    db = key.soc()
    tb = _jaxk.build_tables(db, key.applications(),
                            governor=key.make_governor(),
                            table=key.schedule_table(), pad_pes=pad_pes)
    return jax.tree_util.tree_map(np.asarray, tb)


def tables_for(scn: Scenario, pad_pes: Optional[int] = None,
               host: bool = False) -> SimTables:
    """The scenario's ``SimTables`` (identical to the legacy ``build_tables``
    call), cached across traces/thermal settings.  ``host=True`` returns the
    numpy-leaf form the chunked/sharded sweep executor streams from
    (DESIGN.md §13)."""
    if host:
        return _cached_tables_host(_tables_key(scn), pad_pes)
    return _cached_tables(_tables_key(scn), pad_pes)


@functools.lru_cache(maxsize=256)
def _cached_nodes(design) -> np.ndarray:
    """Thermal node per PE for a design (depends on the design alone)."""
    return np.asarray(cluster_nodes(design.to_db()), np.int32)


@functools.partial(jax.jit, static_argnames=("bins", "repeats"))
def _peak_temp_single(start, finish, onpe, scheduled, nodes, p_act, p_idle,
                      makespan, bins, repeats):
    """One schedule's RC peak temperature (jitted; compiles per shape)."""
    power_trace, dt_s = _thermal_jax.binned_power_trace(
        start, finish, onpe, scheduled, nodes, p_act, p_idle, makespan,
        bins=bins)
    return _thermal_jax.peak_temperature(power_trace, dt_s, repeats=repeats)


def run(scenario: Scenario, backend: str = "ref", *,
        trace_override=None, telemetry: Optional[bool] = None) -> Result:
    """Simulate one scenario.

    ``backend="ref"``: the event-heap reference kernel — all governors and
    fail-stop injection supported.  ``backend="jax"``: the vectorised kernel
    — every governor, static or dynamic: static governors bake one OPP into
    the tables and report the binned RC co-simulation's peak temperature;
    the ondemand family runs the closed DTPM loop inside the epoch scan and
    reports the peak temperature of its inline RC feedback (DESIGN.md §7).
    Both kernels honour fail-stop ``scenario.failures`` bit-for-bit on
    comm-free traces (DESIGN.md §14); the jax backend needs a runtime
    scheduler (met/etf) for graceful degradation and defers to ``ref`` for
    in-loop telemetry under dynamic-governor faults.  Both return the same
    :class:`Result` surface, carrying an ``obs.metrics`` run manifest.

    ``trace_override``: a pre-materialised ``JobTrace`` replacing the
    scenario's trace spec (plumbing for ``sweep`` axes that carry explicit
    traces).

    ``telemetry`` (default: ``scenario.telemetry``): also record per-window
    (W, C) frequency/utilisation/power/temperature timelines on
    ``Result.telemetry`` (DESIGN.md §11).  Observation-only: with a dynamic
    governor the ref kernel records its sampling windows in-loop and the jax
    backend replays the kernel's window carry as a separate jitted scan —
    the simulation program and its outputs are identical either way
    (asserted in tests/test_obs.py).
    """
    want_tel = scenario.telemetry if telemetry is None else bool(telemetry)

    if backend == "ref":
        db = scenario.soc()
        pol = scenario.make_policy()
        governor = scenario.make_governor()
        rec = None
        if want_tel and pol.dynamic:
            rec = _obs_tel.TelemetryRecorder(pol.sample_window_us)
        res = _refk.simulate(db, scenario.applications(),
                             trace_override or scenario.job_trace(),
                             scenario.make_scheduler(), governor,
                             failures=_faults.ref_failures(scenario.failures),
                             telemetry=rec)
        tel = None
        if want_tel:
            tel = (rec.build(_obs_tel.domain_count(db)) if rec is not None
                   else _obs_tel.ref_static_telemetry(db, res, governor))
        result = Result.from_ref(scenario, db, res, telemetry=tel)

    elif backend == "jax":
        # no-op fault specs (empty / all-inf) normalise to plan=None here, so
        # they take the exact fault-free program — same trace, same cache key
        # (the §14 no-op contract, asserted via sweep.compile_count in tests).
        plan = _faults.fault_plan(scenario.failures, scenario.design.num_pes)
        if plan is not None and scenario.scheduler == "table":
            raise BackendCapabilityError(
                "fail-stop injection with the 'table' scheduler", "jax",
                "backend='ref'",
                detail="the offline ILP table pins tasks to PEs, so dead-PE "
                       "fallback needs the runtime schedulers (met/etf)")
        tables = tables_for(scenario)
        trace = trace_override or scenario.job_trace()
        pol = scenario.make_policy()
        if pol.dynamic:
            if plan is not None and want_tel:
                raise BackendCapabilityError(
                    "telemetry with faults under a dynamic governor", "jax",
                    "backend='ref' (it records sampling windows in-loop)",
                    detail="fail-stop rollback breaks the window-closure "
                           "invariant the post-hoc replay assumes")
            out = _jaxk.simulate_jax_dtpm(tables, scenario.scheduler,
                                          trace.arrival_us, trace.app_index,
                                          pol, faults=plan)
            tel = (_obs_tel.jax_dtpm_telemetry(tables, pol, out,
                                               trace.app_index)
                   if want_tel else None)
            result = Result.from_jax(scenario, out, scenario.design.num_pes,
                                     float(out["peak_temp_c"]), telemetry=tel)
        else:
            out = _jaxk.simulate_jax(tables, scenario.scheduler,
                                     trace.arrival_us, trace.app_index,
                                     faults=plan)
            peak = _peak_temp_single(
                out["start"], out["finish"], out["onpe"], out["scheduled"],
                _cached_nodes(scenario.design),
                tables.power_active, tables.power_idle, out["makespan_us"],
                bins=scenario.thermal.bins, repeats=scenario.thermal.repeats)
            tel = (_obs_tel.jax_static_telemetry(
                       scenario.soc(), scenario.make_governor(), tables, out,
                       trace.app_index)
                   if want_tel else None)
            result = Result.from_jax(scenario, out, scenario.design.num_pes,
                                     float(peak), telemetry=tel)
    else:
        raise ScenarioError(f"unknown backend {backend!r}; have {BACKENDS}")

    result.manifest = _metrics.run_manifest(scenario=scenario,
                                            backend=backend)
    return result
