"""Typed error hierarchy for the scenario facade (DESIGN.md §9, §14).

All facade validation errors derive from :class:`ScenarioError`, which
itself derives from ``ValueError`` so pre-existing ``except ValueError``
call sites (and tests pinning ``pytest.raises(ValueError)``) keep working
through the transition.

* :class:`BackendCapabilityError` — the requested feature exists, but not
  on the requested backend; the message names the capability and the
  backend(s) that do have it.
* :class:`LaneAxisError` — a ``sweep`` axis (name, value, or combination)
  is malformed or unsupported.
"""
from __future__ import annotations


class ScenarioError(ValueError):
    """Base class for scenario facade configuration errors."""


class BackendCapabilityError(ScenarioError):
    """A capability is not available on the requested backend.

    Constructed with the capability, the backend that was asked, and the
    backend(s) that support it, so messages are uniformly actionable.
    """

    def __init__(self, capability: str, backend: str, supported: str,
                 detail: str = ""):
        self.capability = capability
        self.backend = backend
        self.supported = supported
        msg = (f"{capability} is not supported on backend={backend!r}; "
               f"use {supported}")
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


class LaneAxisError(ScenarioError):
    """A sweep lane axis is unknown, malformed, or inconsistent."""
