"""``sweep(scenario, axes={...})`` — cross-product scenario batches.

Any combination of scenario axes — arrival rate × scheduler × design point ×
frequency cap × governor policy × seed — is expanded into one batch.  Axes
factorise into four kinds (see DESIGN.md §9–10):

* **design-affecting** (``design``, ``design.<field>``): each combination
  becomes a padded ``SimTables`` lane, reusing ``repro.dse.batch``'s
  inert-padding scheme (pad every design to the widest PE count, stack
  leaf-wise);
* **policy** (``governor``, ``governor_params``): static governors bake into
  the tables and behave like design axes; *dynamic* (ondemand-family)
  governors become stacked :class:`~repro.core.dvfs.GovernorPolicy` lanes
  vmapped through the closed-loop DTPM kernel — hundreds of policy
  parameterisations per compiled program, peak temperature from the inline
  RC loop;
* **trace-affecting** (``trace``, ``trace.<field>``, aliases ``rate`` /
  ``seed`` / ``jobs``): each combination becomes a stacked workload row;
* **faults** (``failures``, alias ``faults``): each value is one fail-stop
  fault set, stacked into ``(F, P)`` fail-time plans and vmapped (outermost,
  so the design axis stays streamable) through the fail-stop kernel — the
  axis adds ZERO compiles per policy shape, and all-no-op axes reuse the
  fault-free program outright (DESIGN.md §14);
* **static** (``scheduler``): a compile-time branch of the kernel — swept in
  an outer python loop, one compiled program per value.

For one scheduler the whole (designs × policies × traces) cross-product runs
as ONE vmapped/jitted tensor program per *policy shape* (static / dynamic) —
and every lane is bit-for-bit equal to a per-point ``run(..., backend="jax")``
(padding is inert; a vmap lane equals a single call; the RC stepper's
spectral e^{A·dt} keeps the thermal math batch-width independent).
``backend="ref"`` sweeps the same cross-product through the event-heap
oracle lane by lane.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.dvfs import stack_policies
from ..core.jobgen import JobTrace
from ..core.simkernel_jax import _simulate_dtpm
from ..dse.batch import (_simulate_grid, _simulate_grid_faults, pad_node_map,
                         stack_tables, stack_traces)
from ..dse.space import DesignPoint
from ..dse.thermal_jax import peak_temperature_grid
from ..obs import metrics as _metrics
from ..obs import telemetry as _obs_tel
from . import faults as _faults
from . import shardexec
from .config import Scenario, TraceSpec
from .errors import BackendCapabilityError, LaneAxisError, ScenarioError
from .result import SweepResult
from .run import run, tables_for

AXIS_ALIASES = {
    "rate": "trace.rate_jobs_per_ms",
    "seed": "trace.seed",
    "jobs": "trace.num_jobs",
    "faults": "failures",
}

_DESIGN_FIELDS = {f.name for f in dataclasses.fields(DesignPoint)}
_TRACE_FIELDS = {f.name for f in dataclasses.fields(TraceSpec)}

# number of times a fused grid program has been traced (re-compiled); the
# one-program-per-policy-shape sweep contract is asserted against this.
# The registered obs counter IS the module attribute — read it via
# ``compile_count.value`` / the ``obs.metrics`` registry (DESIGN.md §11;
# the deprecated ``compile_count[0]`` list alias is gone).
compile_count = _metrics.counter("scenario.sweep.compile_count")


def _canon(name: str) -> str:
    return AXIS_ALIASES.get(name, name)


def _axis_kind(name: str) -> str:
    name = _canon(name)
    if name == "scheduler":
        return "static"
    if name == "failures":
        return "faults"
    if name in ("governor", "governor_params"):
        return "policy"
    if name == "design":
        return "design"
    if name.startswith("design."):
        field = name.split(".", 1)[1]
        if field not in _DESIGN_FIELDS:
            raise LaneAxisError(f"unknown design axis field {field!r}")
        return "design"
    if name == "trace":
        return "trace"
    if name.startswith("trace."):
        field = name.split(".", 1)[1]
        if field not in _TRACE_FIELDS:
            raise LaneAxisError(f"unknown trace axis field {field!r}")
        return "trace"
    raise LaneAxisError(
        f"unknown sweep axis {name!r}; use 'design', 'design.<field>', "
        f"'governor', 'governor_params', 'scheduler', 'trace', "
        f"'trace.<field>', 'failures' or aliases {sorted(AXIS_ALIASES)}")


def _apply_axes(scn: Scenario, names: Sequence[str],
                values: Sequence) -> Scenario:
    """Apply axis values to a scenario ('trace'-axis JobTraces excluded)."""
    for name, value in zip(names, values):
        name = _canon(name)
        if name == "trace" and isinstance(value, JobTrace):
            continue                       # materialised out-of-band
        scn = scn.replace(**{name: value})
    return scn


def _lane_trace(scn: Scenario, names: Sequence[str],
                values: Sequence) -> JobTrace:
    for name, value in zip(names, values):
        if _canon(name) == "trace" and isinstance(value, JobTrace):
            return value
    return scn.job_trace()


@functools.partial(jax.jit, static_argnames=("policy", "num_jobs", "bins",
                                             "repeats"))
def _sweep_grid(tables, node_of_pe, arrival, app_idx, policy, num_jobs,
                bins, repeats):
    """Schedule simulation + thermal scan for (D, S) lanes, ONE program."""
    compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    out = _simulate_grid(tables, policy, num_jobs, arrival, app_idx)
    temps = peak_temperature_grid(out, node_of_pe, tables.power_active,
                                  tables.power_idle, bins=bins,
                                  repeats=repeats)
    return out, temps


@functools.partial(jax.jit, static_argnames=("policy", "num_jobs"))
def _sweep_grid_dtpm(tables, gov, arrival, app_idx, policy, num_jobs):
    """Closed-loop DTPM lanes: (D designs, G policies, S traces) in ONE
    program.  Peak temperature comes from the kernel's inline RC loop (the
    one the throttle feedback integrates), so no post-hoc thermal scan."""
    compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    per_trace = jax.vmap(
        lambda tb, g, a, i: _simulate_dtpm(tb, policy, num_jobs, a, i, g),
        in_axes=(None, None, 0, 0))
    per_policy = jax.vmap(per_trace, in_axes=(None, 0, None, None))
    per_design = jax.vmap(per_policy, in_axes=(0, None, None, None))
    return per_design(tables, gov, arrival, app_idx)


@functools.partial(jax.jit, static_argnames=("policy", "num_jobs", "bins",
                                             "repeats", "scan_steps"))
def _sweep_grid_faults(tables, node_of_pe, fplans, arrival, app_idx, policy,
                       num_jobs, bins, repeats, scan_steps):
    """Fail-stop lanes (F fault plans, D designs, S traces), ONE program.

    The fault axis is outermost so the design axis stays streamable by the
    chunked/sharded executor; the thermal scan vmaps per fault lane over the
    same (D, S) grid program the fault-free path uses (DESIGN.md §14)."""
    compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    out = _simulate_grid_faults(tables, policy, num_jobs, arrival, app_idx,
                                fplans, scan_steps)
    temps = jax.vmap(lambda o: peak_temperature_grid(
        o, node_of_pe, tables.power_active, tables.power_idle, bins=bins,
        repeats=repeats))(out)
    return out, temps


@functools.partial(jax.jit,
                   static_argnames=("policy", "num_jobs", "scan_steps"))
def _sweep_grid_dtpm_faults(tables, gov, fplans, arrival, app_idx, policy,
                            num_jobs, scan_steps):
    """Fail-stop DTPM lanes: (F fault plans, D designs, G policies,
    S traces) through the closed-loop kernel in ONE program."""
    compile_count.inc()  # lint: waive JX003 -- deliberate: counts compiles, python body runs per trace
    per_trace = jax.vmap(
        lambda tb, g, a, i, fp: _simulate_dtpm(tb, policy, num_jobs, a, i, g,
                                               fp, scan_steps=scan_steps),
        in_axes=(None, None, 0, 0, None))
    per_policy = jax.vmap(per_trace, in_axes=(None, 0, None, None, None))
    per_design = jax.vmap(per_policy, in_axes=(0, None, None, None, None))
    per_fault = jax.vmap(per_design, in_axes=(None, None, None, None, 0))
    return per_fault(tables, gov, arrival, app_idx, fplans)


def _design_lanes(base: Scenario, design_axes: List[str],
                  combos: List[Tuple], pad_pes: Optional[int],
                  host: bool = False):
    """Padded+stacked tables and thermal-node map for the design lanes.

    ``host=True`` stacks numpy leaves (the chunked/sharded executor's
    streaming source — the full grid never becomes device-resident)."""
    scns = [_apply_axes(base, design_axes, c) for c in combos]
    dbs = [s.soc() for s in scns]
    P = max(db.num_pes for db in dbs)
    if pad_pes is not None:
        if pad_pes < P:
            raise ValueError(f"pad_pes={pad_pes} < widest design {P}")
        P = pad_pes
    tables = stack_tables([tables_for(s, pad_pes=P, host=host) for s in scns],
                          host=host)
    return tables, pad_node_map(dbs, P)


def sweep(scenario: Scenario, axes: Dict[str, Sequence],
          backend: str = "jax", pad_pes: Optional[int] = None,
          design_batch=None, telemetry: Optional[bool] = None,
          chunk: Optional[int] = None,
          shard: Optional[bool] = None) -> SweepResult:
    """Simulate the cross-product of ``axes`` around ``scenario``.

    ``axes`` maps axis names to value sequences; result arrays are shaped
    ``tuple(len(v) for v in axes.values())`` in dict order.  ``pad_pes``
    fixes the padded PE width (jit-cache stability across design mixes);
    ``design_batch`` (a prebuilt ``repro.dse.DesignBatch``) short-circuits
    table construction when the caller already stacked the design axis —
    it must correspond to a single ``"design"`` axis with matching points.

    ``telemetry`` (default: ``scenario.telemetry``) fills
    ``SweepResult.telemetry`` with one per-window
    :class:`~repro.obs.telemetry.Telemetry` per lane (an object array shaped
    like the axes).  On the jax backend the lanes' timelines are replayed
    from the already-computed grid outputs through the kernels' jitted
    telemetry scans — the simulations are not re-run (DESIGN.md §11).

    ``chunk``/``shard`` scale the design/policy lane axis (jax backend only,
    DESIGN.md §13): ``shard`` splits the lanes across the local devices via
    a ``NamedSharding`` over ``repro.sharding.lane_mesh()`` (default
    ``None`` = auto — shard exactly when more than one device is present;
    ``False`` pins the single-device path); ``chunk=N`` streams the lanes
    through ONE compiled program in fixed-shape N-lane chunks with donated
    input buffers, bounding peak device memory at O(chunk) instead of
    O(grid).  Both are bit-for-bit equal to the unsharded sweep — lanes are
    independent, and uneven lane counts are padded with inert (dropped)
    lanes — and neither adds compiles per policy shape.
    """
    if not axes:
        raise ValueError("axes must name at least one swept dimension")
    if chunk is not None and (not isinstance(chunk, int) or chunk < 1):
        raise ValueError(f"chunk must be a positive lane count, got {chunk!r}")
    names = list(axes)
    values = {n: tuple(axes[n]) for n in names}
    if any(len(v) == 0 for v in values.values()):
        raise ValueError("every sweep axis needs at least one value")
    canon = [_canon(n) for n in names]
    if len(set(canon)) != len(canon):
        dups = sorted({c for c in canon if canon.count(c) > 1})
        raise ValueError(
            f"duplicate sweep axes after alias resolution: {dups} "
            f"(e.g. 'seed' and 'trace.seed' name the same field)")
    kinds = {n: _axis_kind(n) for n in names}
    static_axes = [n for n in names if kinds[n] == "static"]
    design_axes = [n for n in names if kinds[n] == "design"]
    policy_axes = [n for n in names if kinds[n] == "policy"]
    trace_axes = [n for n in names if kinds[n] == "trace"]
    # a whole-object axis would silently overwrite per-field axes of the
    # same object (duplicated lanes, no error) — reject the combination
    for whole in ("trace", "design"):
        fields = [n for n in names if _canon(n).startswith(whole + ".")]
        if whole in canon and fields:
            raise ValueError(
                f"axis '{whole}' conflicts with per-field axes {fields}: "
                f"a whole-'{whole}' value replaces the fields those axes set")

    want_tel = scenario.telemetry if telemetry is None else bool(telemetry)
    if backend == "ref":
        if chunk is not None or shard:
            raise BackendCapabilityError(
                "jax-backend lane options (chunk/shard)", "ref",
                "backend='jax'",
                detail="the ref backend runs lane by lane already")
        return _sweep_ref(scenario, names, values, want_tel)
    if backend != "jax":
        raise ScenarioError(f"unknown backend {backend!r}; have "
                            f"('ref', 'jax')")
    mesh = shardexec.resolve_mesh(shard)
    lane_exec = chunk is not None or mesh is not None

    # fault lanes: every value of a 'faults'/'failures' axis is one fault
    # set; with no such axis the base scenario's failures apply to all lanes
    fault_axes = [n for n in names if kinds[n] == "faults"]
    fault_sets = ([_faults.normalize_failures(v)
                   for v in values[fault_axes[0]]] if fault_axes
                  else [scenario.failures])
    have_faults = any(not f.is_noop for fs in fault_sets for f in fs)

    # classify the governor lanes by policy shape: static governors bake
    # into the tables (design-kind lanes), the dynamic ondemand family
    # becomes vmapped GovernorPolicy lanes through the DTPM kernel
    policy_combos = list(itertools.product(
        *(values[n] for n in policy_axes))) or [()]
    pol_scns = [_apply_axes(scenario, policy_axes, c) for c in policy_combos]
    policies = [s.make_policy() for s in pol_scns]
    dyn_flags = {p.dynamic for p in policies}
    if len(dyn_flags) > 1:
        raise LaneAxisError(
            "a sweep cannot mix static and dynamic (ondemand-family) "
            "governors in one batch — they compile to different policy "
            "shapes; split the sweep per governor kind (DESIGN.md §10)")
    dynamic = dyn_flags.pop()
    if not dynamic:
        design_axes = design_axes + policy_axes   # baked into table lanes
        policy_axes = []
    if have_faults and dynamic and want_tel:
        raise BackendCapabilityError(
            "telemetry with faults under a dynamic governor", "jax",
            "backend='ref' (it records sampling windows in-loop)",
            detail="fail-stop rollback breaks the window-closure invariant "
                   "the post-hoc replay assumes")

    static_combos = list(itertools.product(
        *(values[n] for n in static_axes))) or [()]
    design_combos = list(itertools.product(
        *(values[n] for n in design_axes))) or [()]
    trace_combos = list(itertools.product(
        *(values[n] for n in trace_axes))) or [()]

    # workloads: one stacked (S, J) pair shared by every design lane
    t_scns = [_apply_axes(scenario, trace_axes, c) for c in trace_combos]
    traces = [_lane_trace(s, trace_axes, c)
              for s, c in zip(t_scns, trace_combos)]
    job_counts = {t.num_jobs for t in traces}
    if len(job_counts) > 1:
        raise LaneAxisError(
            f"the jax backend needs equal job counts per lane to stack one "
            f"(S, J) workload tensor, got {sorted(job_counts)}; sweep the "
            f"'jobs' axis with backend='ref' instead")
    arrival, app_idx = stack_traces(traces)
    num_jobs = int(arrival.shape[1])

    # design-lane base: dynamic tables carry the OPP ladders, so the (first)
    # dynamic governor must be applied before tables are built; every dynamic
    # parameterisation shares the same tables (run._tables_key collapses them)
    lane_base = pol_scns[0] if dynamic else scenario

    if design_batch is not None:
        if design_axes != ["design"] or tuple(
                values["design"]) != design_batch.points:
            raise ValueError("design_batch requires a single 'design' axis "
                             "matching design_batch.points")
        if dynamic:
            if design_batch.tables.exec_opp is None:
                raise ValueError(
                    "design_batch tables lack the OPP ladders a dynamic "
                    "governor needs; build them with "
                    "build_design_batch(..., governor=<dynamic governor>)")
        elif design_batch.tables.exec_opp is not None:
            # dynamic-built tables bake exec_us at the ondemand initial
            # (fmin) OPP — running the static kernel on them would silently
            # break the per-point run() equivalence contract
            raise ValueError(
                "design_batch was built for a dynamic governor; a static "
                "sweep needs build_design_batch(...) without one")
        elif scenario.governor != "design":
            # build_design_batch bakes each point's frequency-cap governor
            # into the tables; any other governor would silently diverge
            # from the per-point run() equivalence contract
            raise ValueError("design_batch tables pin the design frequency "
                             "caps; the scenario must use governor='design'")
        if int(design_batch.tables.exec_us.shape[1]) \
                != len(scenario.applications()):
            raise ValueError("design_batch was built for a different "
                             "application list than the scenario's")
        tables, node_of_pe = design_batch.tables, design_batch.node_of_pe

    # tables depend on the static (scheduler) axis only through the offline
    # ILP table — hoist the (D, …) stack out of the loop unless a swept
    # combo actually selects the "table" policy
    any_table = any(
        _apply_axes(lane_base, static_axes, sc).scheduler == "table"
        for sc in static_combos)
    if have_faults and any_table:
        raise BackendCapabilityError(
            "fail-stop injection with the 'table' scheduler", "jax",
            "backend='ref'",
            detail="the offline ILP table pins tasks to PEs, so dead-PE "
                   "fallback needs the runtime schedulers (met/etf)")
    rebuild_per_combo = design_batch is None and any_table
    if design_batch is None and not rebuild_per_combo:
        tables, node_of_pe = _design_lanes(lane_base, design_axes,
                                           design_combos, pad_pes,
                                           host=lane_exec)

    gov_stack = stack_policies(policies) if dynamic else None

    # stacked (F, P) fault plans: pe_ids validate against the narrowest
    # design lane; plans are emitted at the padded PE width.  All-noop lanes
    # leave plans=None — the sweep then runs the exact fault-free program
    # (zero extra compiles) and tiles its results over the fault axis.
    plans, scan_steps = None, None
    if have_faults:
        min_pes = min(_apply_axes(lane_base, design_axes, c).design.num_pes
                      for c in design_combos)
        plans, max_f = _faults.stack_fault_plans(
            fault_sets, min_pes, width=int(tables.num_pes))
        scan_steps = _faults.fault_scan_steps(num_jobs, int(tables.t_max),
                                              max_f)

    per_static = []
    for sc in static_combos:
        s_scn = _apply_axes(lane_base, static_axes, sc)
        if rebuild_per_combo:
            tables, node_of_pe = _design_lanes(s_scn, design_axes,
                                               design_combos, pad_pes,
                                               host=lane_exec)
        if dynamic:
            if plans is not None:
                if lane_exec:
                    out = shardexec.run_dtpm_grid(
                        tables, gov_stack, arrival, app_idx,
                        policy=s_scn.scheduler, num_jobs=num_jobs,
                        chunk=chunk, mesh=mesh, fplans=plans,
                        scan_steps=scan_steps)
                else:
                    out = _sweep_grid_dtpm_faults(tables, gov_stack, plans,
                                                  arrival, app_idx,
                                                  policy=s_scn.scheduler,
                                                  num_jobs=num_jobs,
                                                  scan_steps=scan_steps)
            elif lane_exec:
                out = shardexec.run_dtpm_grid(tables, gov_stack, arrival,
                                              app_idx,
                                              policy=s_scn.scheduler,
                                              num_jobs=num_jobs,
                                              chunk=chunk, mesh=mesh)
            else:
                out = _sweep_grid_dtpm(tables, gov_stack, arrival, app_idx,
                                       policy=s_scn.scheduler,
                                       num_jobs=num_jobs)
            temps = out["peak_temp_c"]
        else:
            if plans is not None:
                if lane_exec:
                    out, temps = shardexec.run_static_grid(
                        tables, node_of_pe, arrival, app_idx,
                        policy=s_scn.scheduler, num_jobs=num_jobs,
                        bins=s_scn.thermal.bins,
                        repeats=s_scn.thermal.repeats,
                        chunk=chunk, mesh=mesh, fplans=plans,
                        scan_steps=scan_steps)
                else:
                    out, temps = _sweep_grid_faults(
                        tables, node_of_pe, plans, arrival, app_idx,
                        policy=s_scn.scheduler, num_jobs=num_jobs,
                        bins=s_scn.thermal.bins,
                        repeats=s_scn.thermal.repeats,
                        scan_steps=scan_steps)
            elif lane_exec:
                out, temps = shardexec.run_static_grid(
                    tables, node_of_pe, arrival, app_idx,
                    policy=s_scn.scheduler, num_jobs=num_jobs,
                    bins=s_scn.thermal.bins, repeats=s_scn.thermal.repeats,
                    chunk=chunk, mesh=mesh)
            else:
                out, temps = _sweep_grid(tables, node_of_pe, arrival, app_idx,
                                         policy=s_scn.scheduler,
                                         num_jobs=num_jobs,
                                         bins=s_scn.thermal.bins,
                                         repeats=s_scn.thermal.repeats)
        if plans is not None and not fault_axes:
            # base-scenario faults, no fault axis: drop the F=1 lane axis so
            # the grid keeps its fault-free shape
            out = {k: v[0] for k, v in out.items()}
            temps = temps[0]
        entry = dict(
            avg_latency_us=np.asarray(out["avg_job_latency_us"], np.float64),
            makespan_us=np.asarray(out["makespan_us"], np.float64),
            energy_j=np.asarray(out["energy_j"], np.float64),
            peak_temp_c=np.asarray(temps, np.float64),
            busy_per_pe_us=np.asarray(out["busy_per_pe_us"], np.float64))
        if want_tel:
            entry["telemetry"] = _telemetry_grid(
                s_scn, design_axes, design_combos, policies, tables,
                app_idx, out, dynamic,
                num_faults=(len(fault_sets)
                            if fault_axes and plans is not None else 0))
        if fault_axes and plans is None:
            # every fault lane is a no-op: the fault-free program ran once
            # (the §14 no-op contract — zero extra compiles) and its results
            # tile verbatim across the fault axis
            entry = {k: np.repeat(v[None], len(fault_sets), axis=0)
                     for k, v in entry.items()}
        per_static.append(entry)

    # assemble: (static..., faults..., design..., policy..., trace..., extra)
    # then the user's axes-dict order
    d_lens = [len(values[n]) for n in design_axes]
    p_lens = [len(values[n]) for n in policy_axes]
    t_lens = [len(values[n]) for n in trace_axes]
    s_lens = [len(values[n]) for n in static_axes]
    f_lens = [len(values[n]) for n in fault_axes]
    internal = static_axes + fault_axes + design_axes + policy_axes \
        + trace_axes
    perm = [internal.index(n) for n in names]
    # (Σstatic[, F], D[, G], S)
    grid_ndim = (4 if dynamic else 3) + (1 if fault_axes else 0)

    def _assemble(key: str) -> np.ndarray:
        stacked = np.stack([g[key] for g in per_static])
        extra = stacked.shape[grid_ndim:]
        arr = stacked.reshape(*s_lens, *f_lens, *d_lens, *p_lens, *t_lens,
                              *extra)
        k = len(internal)
        return np.transpose(arr, axes=perm + list(range(k, arr.ndim)))

    makespan = _assemble("makespan_us")
    return SweepResult(
        base=scenario, backend="jax", axes=values,
        avg_latency_us=_assemble("avg_latency_us"),
        throughput_jobs_per_ms=num_jobs / np.maximum(makespan, 1e-9) * 1e3,
        makespan_us=makespan, energy_j=_assemble("energy_j"),
        peak_temp_c=_assemble("peak_temp_c"),
        busy_per_pe_us=_assemble("busy_per_pe_us"),
        telemetry=_assemble("telemetry") if want_tel else None)


def _telemetry_grid(s_scn: Scenario, design_axes: List[str],
                    design_combos: List[Tuple], policies, tables,
                    app_idx, out, dynamic: bool,
                    num_faults: int = 0) -> np.ndarray:
    """Per-lane :class:`Telemetry` objects for one static combo, as an
    object array shaped like the internal grid ((D, G, S) dynamic,
    (D, S) static).  Each lane slices the stacked tables (leaf-wise) and the
    grid outputs, then replays the kernel's jitted telemetry scan — the
    simulation itself is not re-run.  ``num_faults > 0`` (static governors
    only — faulted dynamic telemetry is rejected upstream) prepends the
    fault-lane axis: the replay runs per fault lane on that lane's final
    schedule, so dead PEs show zero utilisation past their fail time."""
    if num_faults:
        return np.stack([
            _telemetry_grid(s_scn, design_axes, design_combos, policies,
                            tables, app_idx,
                            {k: v[f] for k, v in out.items()}, dynamic)
            for f in range(num_faults)])
    keys = ("scheduled", "start", "finish", "onpe", "makespan_us")
    D = len(design_combos)
    S = int(np.asarray(app_idx).shape[0])
    if dynamic:
        G = len(policies)
        grid = np.empty((D, G, S), object)
        for d in range(D):
            tb = jax.tree_util.tree_map(lambda x, _d=d: x[_d], tables)
            for g in range(G):
                for s in range(S):
                    out_l = {k: out[k][d, g, s] for k in keys + ("onopp",)}
                    grid[d, g, s] = _obs_tel.jax_dtpm_telemetry(
                        tb, policies[g], out_l, app_idx[s])
        return grid
    grid = np.empty((D, S), object)
    for d in range(D):
        tb = jax.tree_util.tree_map(lambda x, _d=d: x[_d], tables)
        lane_scn = _apply_axes(s_scn, design_axes, design_combos[d])
        db, gov = lane_scn.soc(), lane_scn.make_governor()
        for s in range(S):
            out_l = {k: out[k][d, s] for k in keys}
            grid[d, s] = _obs_tel.jax_static_telemetry(db, gov, tb, out_l,
                                                       app_idx[s])
    return grid


def _sweep_ref(scenario: Scenario, names: List[str],
               values: Dict[str, Tuple],
               want_tel: bool = False) -> SweepResult:
    """Cross-product sweep through the reference kernel, lane by lane."""
    shape = tuple(len(values[n]) for n in names)
    lanes = list(itertools.product(*(values[n] for n in names)))
    results = []
    for combo in lanes:
        scn = _apply_axes(scenario, names, combo)
        trace = _lane_trace(scn, names, combo)
        results.append(run(scn, backend="ref", trace_override=trace,
                           telemetry=want_tel))
    P = max(r.utilization.shape[0] for r in results)
    busy = np.zeros((len(lanes), P), np.float64)
    for i, r in enumerate(results):
        busy[i, :r.utilization.shape[0]] = r.utilization * r.makespan_us

    def _arr(field):
        return np.asarray([getattr(r, field) for r in results],
                          np.float64).reshape(shape)

    tel = None
    if want_tel:
        tel = np.empty(len(lanes), object)
        tel[:] = [r.telemetry for r in results]
        tel = tel.reshape(shape)
    return SweepResult(
        base=scenario, backend="ref", axes=values,
        avg_latency_us=_arr("avg_latency_us"),
        throughput_jobs_per_ms=_arr("throughput_jobs_per_ms"),
        makespan_us=_arr("makespan_us"), energy_j=_arr("energy_j"),
        peak_temp_c=_arr("peak_temp_c"),
        busy_per_pe_us=busy.reshape(*shape, P),
        telemetry=tel)
