"""Shared benchmark CLI harness (DESIGN.md §11).

Every ``benchmarks/bench_*`` module exposes ``run() -> [(name, value,
derived), ...]``; :func:`bench_cli` is the one ``main()`` they all share —
it prints the CSV rows, and under ``--json`` writes a schema-tagged payload
``{"manifest": <run manifest>, "rows": [...]}`` so every ``BENCH_*.json``
is self-describing (scenario hashes, device platform, jit compile counts,
wall time).  ``python -m repro.obs.report BENCH_x.json`` renders these.

``--smoke`` asks the benchmark for scaled-down inputs: a ``run(smoke=...)``
signature receives the flag and picks sizes via :func:`scaled`.  Compile
*counts* are size-independent (jit keys on static argnames and shapes held
fixed within a run), so the CC001 compile-count gate
(``python -m repro.analysis --compile-gate``) runs on smoke artifacts and
still guards the full-size benchmarks.
"""
from __future__ import annotations

import argparse
import inspect
import json
from typing import Callable, List, Optional, Sequence, Tuple

from . import metrics

BENCH_SCHEMA = "repro.obs/bench/v1"

Rows = List[Tuple[str, float, str]]


def scaled(full, smoke_value, smoke: bool):
    """Pick the benchmark input size: ``full`` normally, ``smoke_value``
    under ``--smoke`` (the fast CI lane feeding the compile-count gate)."""
    return smoke_value if smoke else full


def rows_payload(rows: Rows, name: str, wall_s: float, **extra) -> dict:
    """The ``BENCH_*.json`` payload: run manifest + measurement rows."""
    return {
        "schema": BENCH_SCHEMA,
        "manifest": metrics.run_manifest(bench=name, wall_s=wall_s, **extra),
        "rows": [dict(name=n, value=float(v), derived=str(d))
                 for n, v, d in rows],
    }


def bench_cli(run_fn: Callable[..., Rows], name: str,
              description: Optional[str] = None,
              argv: Optional[Sequence[str]] = None) -> int:
    """Run one benchmark module as a CLI: print the CSV rows, honour the
    ``--json PATH`` and ``--smoke`` flags (the CI perf artifact lane)."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--json", metavar="PATH",
                    help="also dump rows + run manifest as JSON "
                         "(CI perf artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down inputs: fast lane for the CC001 "
                         "compile-count gate")
    ap.add_argument("--devices", type=int, default=1, metavar="N",
                    help="virtual host device count (XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N); must "
                         "take effect before the first jax import, so it is "
                         "applied by the benchmarks' pre-import shim "
                         "(benchmarks._devices) — declared here only for "
                         "--help and validation (default: 1)")
    args = ap.parse_args(argv)
    kwargs = {}
    try:
        if "smoke" in inspect.signature(run_fn).parameters:
            kwargs["smoke"] = args.smoke
    except (TypeError, ValueError):                     # pragma: no cover
        pass
    wall = metrics.timer(f"bench.{name}.wall")
    with wall:
        rows = run_fn(**kwargs)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.4f},{d}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rows_payload(rows, name, wall.last_s,
                                   smoke=args.smoke), fh, indent=2)
    return 0
