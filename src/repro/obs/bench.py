"""Shared benchmark CLI harness (DESIGN.md §11).

Every ``benchmarks/bench_*`` module exposes ``run() -> [(name, value,
derived), ...]``; :func:`bench_cli` is the one ``main()`` they all share —
it prints the CSV rows, and under ``--json`` writes a schema-tagged payload
``{"manifest": <run manifest>, "rows": [...]}`` so every ``BENCH_*.json``
is self-describing (scenario hashes, device platform, jit compile counts,
wall time).  ``python -m repro.obs.report BENCH_x.json`` renders these.
"""
from __future__ import annotations

import argparse
import json
from typing import Callable, List, Optional, Sequence, Tuple

from . import metrics

BENCH_SCHEMA = "repro.obs/bench/v1"

Rows = List[Tuple[str, float, str]]


def rows_payload(rows: Rows, name: str, wall_s: float) -> dict:
    """The ``BENCH_*.json`` payload: run manifest + measurement rows."""
    return {
        "schema": BENCH_SCHEMA,
        "manifest": metrics.run_manifest(bench=name, wall_s=wall_s),
        "rows": [dict(name=n, value=float(v), derived=str(d))
                 for n, v, d in rows],
    }


def bench_cli(run_fn: Callable[[], Rows], name: str,
              description: Optional[str] = None,
              argv: Optional[Sequence[str]] = None) -> int:
    """Run one benchmark module as a CLI: print the CSV rows, honour the
    ``--json PATH`` flag (the CI perf artifact)."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--json", metavar="PATH",
                    help="also dump rows + run manifest as JSON "
                         "(CI perf artifact)")
    args = ap.parse_args(argv)
    wall = metrics.timer(f"bench.{name}.wall")
    with wall:
        rows = run_fn()
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.4f},{d}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rows_payload(rows, name, wall.last_s), fh, indent=2)
    return 0
