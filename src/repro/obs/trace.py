"""Chrome trace-event JSON (Perfetto-loadable) schedule traces (DESIGN.md §11).

:func:`chrome_trace` serialises a reference-kernel :class:`SimResult` to the
Chrome trace-event format (https://ui.perfetto.dev loads it directly):

* one *thread* track per PE (``tid`` = PE id) carrying matched ``B``/``E``
  duration events per committed task — args record the decision epoch
  (ready), job/task ids and the DVFS frequency latched at dispatch — plus an
  instant (``ph: "i"``) marker at each task's ready time;
* *counter* tracks (``ph: "C"``) per sampling window from an optional
  :class:`~repro.obs.telemetry.Telemetry`: per-cluster frequency (GHz),
  per-cluster utilisation, per-node temperature (°C).

All timestamps are microseconds (the simulator's native unit — trace-event
``ts`` is defined in µs).  :func:`validate_chrome_trace` checks the schema
invariants the tests pin: required keys, non-decreasing ``ts``, matched
``B``/``E`` pairs per track.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

TRACE_PID = 0
_PH_ORDER = {"E": 0, "B": 1, "i": 2, "C": 3}   # equal-ts tie-break: close
                                               # the previous slice first


def chrome_trace(db, result, apps: Optional[Sequence] = None,
                 trace=None, telemetry=None, failures=None,
                 label: str = "repro-soc") -> Dict:
    """Build the trace-event dict for one reference run.

    ``apps``/``trace`` (the Application list and JobTrace) are optional and
    only used to resolve human-readable task names; without them tasks are
    labelled ``j<job>.t<task>``.  ``telemetry`` adds the counter tracks.
    ``failures`` (the scenario's fail-stop specs, DESIGN.md §14) adds a
    process-scoped instant marker on each dying PE's track at its fail time,
    so the rollback gap and the survivors' pile-up line up visually.
    """
    meta: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": TRACE_PID, "tid": 0,
        "args": {"name": label},
    }]
    for j, pe in enumerate(db.pes):
        meta.append({"name": "thread_name", "ph": "M", "pid": TRACE_PID,
                     "tid": j, "args": {"name": f"PE{j} {pe.name}"}})
        meta.append({"name": "thread_sort_index", "ph": "M", "pid": TRACE_PID,
                     "tid": j, "args": {"sort_index": j}})

    def task_name(jid: int, tid: int) -> str:
        if apps is not None and trace is not None:
            app = apps[int(trace.app_index[jid])]
            return f"{app.name}.{app.tasks[tid].name}"
        return f"j{jid}.t{tid}"

    events: List[Dict] = []
    for r in result.records:
        name = task_name(r.job_id, r.task_id)
        args = {"job": r.job_id, "task": r.task_id,
                "ready_us": r.ready_us, "freq_ghz": r.freq_ghz}
        events.append({"name": name, "ph": "B", "pid": TRACE_PID,
                       "tid": r.pe_id, "ts": r.start_us, "args": args})
        events.append({"name": name, "ph": "E", "pid": TRACE_PID,
                       "tid": r.pe_id, "ts": r.finish_us})
        events.append({"name": f"ready {name}", "ph": "i", "s": "t",
                       "pid": TRACE_PID, "tid": r.pe_id, "ts": r.ready_us})

    if failures:
        # lazy import: obs must stay importable without the scenario facade
        from ..scenario.faults import normalize_failures
        for f in normalize_failures(failures):
            if f.is_noop:
                continue
            events.append({
                "name": f"FAIL-STOP PE{f.pe_id}", "ph": "i", "s": "p",
                "pid": TRACE_PID, "tid": f.pe_id,
                "ts": float(f.fail_time_us),
                "args": {"pe": f.pe_id, "kind": f.kind}})

    if telemetry is not None and telemetry.num_windows:
        t_us = telemetry.time_us
        C = telemetry.num_domains
        for w in range(telemetry.num_windows):
            ts = float(t_us[w])
            events.append({
                "name": "freq_ghz", "ph": "C", "pid": TRACE_PID, "ts": ts,
                "args": {f"cl{c}": float(telemetry.freq_ghz[w, c])
                         for c in range(C)}})
            events.append({
                "name": "util", "ph": "C", "pid": TRACE_PID, "ts": ts,
                "args": {f"cl{c}": float(telemetry.util[w, c])
                         for c in range(C)}})
            events.append({
                "name": "temp_c", "ph": "C", "pid": TRACE_PID, "ts": ts,
                "args": {n: float(telemetry.temps_c[w, i])
                         for i, n in enumerate(("big", "little", "accel",
                                                "board"))}})

    events.sort(key=lambda e: (e["ts"], _PH_ORDER.get(e["ph"], 9)))
    return {"displayTimeUnit": "ms", "traceEvents": meta + events}


def write_chrome_trace(path, trace_obj: Dict) -> None:
    with open(path, "w") as fh:
        json.dump(trace_obj, fh)


def validate_chrome_trace(trace_obj: Dict) -> List[str]:
    """Schema check; returns the list of violations (empty = valid).

    Invariants: a ``traceEvents`` list whose entries carry the required keys
    (``name``/``ph``/``pid``, ``ts`` for non-metadata, ``tid`` for thread
    events), non-decreasing ``ts`` in serialised order, and balanced
    ``B``/``E`` pairs per ``(pid, tid)`` with ``E.ts ≥ B.ts``.
    """
    errs: List[str] = []
    events = trace_obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list 'traceEvents'"]
    last_ts = None
    stacks: Dict = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        for key in ("name", "ph", "pid"):
            if key not in e:
                errs.append(f"event {i}: missing key {key!r}")
        if ph == "M":
            continue
        if "ts" not in e:
            errs.append(f"event {i}: missing key 'ts'")
            continue
        ts = e["ts"]
        if last_ts is not None and ts < last_ts:
            errs.append(f"event {i}: ts {ts} < previous {last_ts} "
                        "(non-monotonic)")
        last_ts = ts
        if ph in ("B", "E", "i"):
            if "tid" not in e:
                errs.append(f"event {i}: thread event missing 'tid'")
                continue
            key = (e.get("pid"), e["tid"])
            if ph == "B":
                stacks.setdefault(key, []).append((e.get("name"), ts, i))
            elif ph == "E":
                stack = stacks.get(key) or []
                if not stack:
                    errs.append(f"event {i}: 'E' with no open 'B' on "
                                f"track {key}")
                    continue
                _, b_ts, _ = stack.pop()
                if ts < b_ts:
                    errs.append(f"event {i}: 'E' ts {ts} precedes its "
                                f"'B' ts {b_ts}")
    for key, stack in stacks.items():
        for name, _, i in stack:
            errs.append(f"event {i}: unmatched 'B' ({name!r}) on track {key}")
    return errs
