"""Named counters and timers + the run manifest.

One process-wide registry replaces ad-hoc instrumentation state that was
scattered through the codebase (the one-time ``compile_count = [0]``
mutable-list hack in ``repro.scenario.sweep``, whose deprecated ``[0]``
alias is now gone, and per-benchmark ``perf_counter`` pairs).  Counters
and timers are cheap plain-python objects — they are incremented inside
jitted python bodies (which run only on trace), so they count *compiles*,
never per-step work.

:func:`run_manifest` snapshots the registry plus the execution environment
(device/platform, versions, scenario hash) into a JSON-ready dict — attached
to every ``Result`` and every ``BENCH_*.json`` so perf artifacts are
self-describing.

This module is stdlib-only at import time (JAX is imported lazily inside
``run_manifest``): the simulation kernels import it for their compile
counters, so it must not import them back.
"""
from __future__ import annotations

import hashlib
import platform as _platform
import time
from typing import Dict, Optional

MANIFEST_SCHEMA = "repro.obs/manifest/v1"


class Counter:
    """A named monotonic counter (``.value`` / ``.inc()`` / ``.reset()``).

    The legacy one-element-list protocol (``c[0]``), deprecated when the
    registry replaced the ``compile_count = [0]`` hack and kept for one
    release, has been removed.
    """
    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    @property
    def value(self) -> int:
        return self._value

    def inc(self, n: int = 1) -> int:
        self._value += n
        return self._value

    def reset(self) -> None:
        self._value = 0

    def __int__(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._value})"


class Timer:
    """A reusable wall-clock timer (``time.perf_counter``) context manager.

    ``with t: ...`` accumulates into ``total_s``/``count`` and exposes the
    most recent interval as ``last_s`` — the one shape every benchmark's
    cold/warm timing boilerplate reduces to.
    """
    __slots__ = ("name", "count", "total_s", "last_s", "_t0")

    def __init__(self, name: Optional[str] = None):
        self.name = name
        self.reset()

    def reset(self) -> None:
        self.count = 0
        self.total_s = 0.0
        self.last_s = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.last_s = time.perf_counter() - self._t0
        self.total_s += self.last_s
        self.count += 1
        return False

    @property
    def last_us(self) -> float:
        return self.last_s * 1e6

    @property
    def avg_s(self) -> float:
        return self.total_s / max(self.count, 1)

    def __repr__(self) -> str:
        return (f"Timer({self.name}: n={self.count}, "
                f"total={self.total_s:.6f}s, last={self.last_s:.6f}s)")


_COUNTERS: Dict[str, Counter] = {}
_TIMERS: Dict[str, Timer] = {}


def counter(name: str) -> Counter:
    """The registered counter ``name`` (created on first use)."""
    c = _COUNTERS.get(name)
    if c is None:
        c = _COUNTERS[name] = Counter(name)
    return c


def timer(name: str) -> Timer:
    """The registered timer ``name`` (created on first use)."""
    t = _TIMERS.get(name)
    if t is None:
        t = _TIMERS[name] = Timer(name)
    return t


def snapshot() -> Dict[str, Dict[str, float]]:
    """JSON-ready registry state: counter values + timer totals."""
    return {
        "counters": {n: c.value for n, c in sorted(_COUNTERS.items())},
        "timers": {n: {"count": t.count, "total_s": t.total_s,
                       "last_s": t.last_s}
                   for n, t in sorted(_TIMERS.items())},
    }


def reset_all() -> None:
    for c in _COUNTERS.values():
        c.reset()
    for t in _TIMERS.values():
        t.reset()


def scenario_hash(scenario) -> str:
    """Stable short hash of a frozen Scenario (its dataclass repr is
    deterministic), usable to correlate runs across processes/artifacts."""
    return hashlib.sha1(repr(scenario).encode()).hexdigest()[:12]


def jit_compile_count() -> int:
    """Total jitted-program traces recorded by the kernel/sweep counters."""
    return sum(c.value for n, c in _COUNTERS.items()
               if n.endswith("compile_count"))


def run_manifest(scenario=None, backend: Optional[str] = None,
                 **extra) -> Dict:
    """A self-describing record of one run: what ran, where, how compiled.

    Fields: schema tag, UTC timestamp, python/JAX versions, device platform
    and kind, total jit compile count plus the full counter/timer snapshot,
    and — when given — the scenario label/hash and backend.  ``extra``
    key-values (wall times, bench name, …) are merged verbatim.
    """
    from datetime import datetime, timezone
    man = {
        "schema": MANIFEST_SCHEMA,
        "timestamp": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "python": _platform.python_version(),
        "host_platform": _platform.platform(),
    }
    try:
        import jax
        man["jax_version"] = jax.__version__
        man["device_platform"] = jax.default_backend()
        man["device_kind"] = jax.devices()[0].device_kind
        man["device_count"] = jax.device_count()
    except Exception:                                      # noqa: BLE001
        man["device_platform"] = "unavailable"
    if scenario is not None:
        man["scenario"] = scenario.label()
        man["scenario_hash"] = scenario_hash(scenario)
    if backend is not None:
        man["backend"] = backend
    man["jit_compile_count"] = jit_compile_count()
    man["metrics"] = snapshot()
    man.update(extra)
    return man
