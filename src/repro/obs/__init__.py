"""repro.obs — unified run instrumentation (DESIGN.md §11).

Three surfaces, one package:

* :mod:`repro.obs.metrics` — named counters/timers (generalising the old
  ``sweep.compile_count`` mutable-list hack) plus the run manifest attached
  to every ``Result`` and every ``BENCH_*.json``;
* :mod:`repro.obs.telemetry` — per-sampling-window ``(W, C)`` timelines of
  cluster frequency, utilisation, power and RC node temperature, recorded by
  both simulation kernels without perturbing them;
* :mod:`repro.obs.trace` — Chrome trace-event JSON (Perfetto-loadable) of
  the realised schedule: one track per PE, counter tracks for frequency and
  temperature.

``python -m repro.obs.report`` renders timeline summaries from run/bench
JSON files and writes the Perfetto trace.

Only :mod:`.metrics` (stdlib-only) is imported eagerly — the simulation
kernels import it for their compile counters, so this package must not
import them back at module scope (lazy re-exports below break the cycle).
"""
from . import metrics
from .metrics import Counter, Timer, counter, run_manifest, scenario_hash, timer

_LAZY = {
    "Telemetry": "telemetry",
    "TelemetryRecorder": "telemetry",
    "chrome_trace": "trace",
    "write_chrome_trace": "trace",
    "validate_chrome_trace": "trace",
    "bench_cli": "bench",
    "scaled": "bench",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)


__all__ = ["metrics", "Counter", "Timer", "counter", "timer", "run_manifest",
           "scenario_hash", *_LAZY]
