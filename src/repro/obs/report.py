"""``python -m repro.obs.report`` — render run/bench artifacts (DESIGN.md §11).

Three things, composable in one invocation:

* positional JSON files — ``BENCH_*.json`` payloads (manifest + rows) or
  telemetry dumps — are rendered as timeline/manifest summaries;
* ``--trace PATH`` [``--telemetry PATH``] — run the scenario described by
  the CLI knobs through the *reference* kernel with telemetry recording and
  write the Perfetto-loadable Chrome trace (and the telemetry JSON): one
  thread track per PE, counter tracks for frequency/utilisation/temperature;
* ``--validate PATH`` — schema-check an existing Chrome trace file (required
  keys, monotonic ts, matched B/E pairs); non-zero exit on violations.

Examples::

    python -m repro.obs.report BENCH_dtpm.json
    python -m repro.obs.report --governor ondemand --trace TRACE_ref.json \
        --telemetry TELEMETRY_ref.json
    python -m repro.obs.report --validate TRACE_ref.json
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence

import numpy as np

from .bench import BENCH_SCHEMA
from .metrics import MANIFEST_SCHEMA
from .telemetry import TELEMETRY_SCHEMA, Telemetry
from .trace import chrome_trace, validate_chrome_trace, write_chrome_trace


def _print_manifest(man: dict) -> None:
    keys = ("timestamp", "scenario", "scenario_hash", "backend", "bench",
            "device_platform", "device_kind", "jax_version",
            "jit_compile_count", "wall_s")
    print("manifest:")
    for k in keys:
        if k in man:
            print(f"  {k:18s} {man[k]}")


def _print_telemetry(tel: Telemetry, label: str = "telemetry") -> None:
    W, C = tel.num_windows, tel.num_domains
    print(f"{label}: {W} windows x {tel.window_us:g} us, {C} domains")
    if W == 0:
        return
    for c in range(C):
        f = tel.freq_ghz[:, c]
        moves = int(np.count_nonzero(np.diff(tel.freq_idx[:, c])))
        print(f"  cl{c}: freq {f.min():.2f}-{f.max():.2f} GHz "
              f"({moves} transitions), util mean "
              f"{tel.util[:, c].mean():.2f} max {tel.util[:, c].max():.2f}")
    print(f"  power: avg {tel.avg_power_w:.3f} W, "
          f"peak temp {tel.peak_temp_c:.2f} C")


def _report_file(path: str) -> None:
    with open(path) as fh:
        payload = json.load(fh)
    schema = payload.get("schema") if isinstance(payload, dict) else None
    print(f"== {path} ==")
    if schema == TELEMETRY_SCHEMA:
        _print_telemetry(Telemetry.from_dict(payload))
    elif schema == BENCH_SCHEMA:
        _print_manifest(payload.get("manifest", {}))
        rows = payload.get("rows", [])
        print(f"rows ({len(rows)}):")
        for r in rows:
            print(f"  {r['name']:40s} {r['value']:>14.4f}  {r['derived']}")
    elif schema == MANIFEST_SCHEMA:
        _print_manifest(payload)
    elif isinstance(payload, dict) and "manifest" in payload:
        _print_manifest(payload["manifest"])
    else:
        print(f"  (unrecognised schema {schema!r} — nothing to render)")


def _run_and_trace(args) -> int:
    from ..scenario import Scenario, TraceSpec, run

    scn = Scenario(
        apps=tuple(args.apps), scheduler=args.scheduler,
        governor=args.governor,
        trace=TraceSpec(rate_jobs_per_ms=args.rate, num_jobs=args.jobs,
                        seed=args.seed))
    res = run(scn, backend="ref", telemetry=True)
    db = scn.soc()
    tr = chrome_trace(db, res.raw, apps=scn.applications(),
                      trace=scn.job_trace(), telemetry=res.telemetry,
                      label=scn.label())
    errs = validate_chrome_trace(tr)
    if errs:
        for e in errs:
            print(f"INTERNAL trace violation: {e}")
        return 1
    write_chrome_trace(args.trace, tr)
    print(f"wrote {args.trace}: {len(tr['traceEvents'])} events "
          f"({len(res.raw.records)} tasks on {db.num_pes} PEs) — "
          f"load it at https://ui.perfetto.dev")
    if args.telemetry_out:
        with open(args.telemetry_out, "w") as fh:
            json.dump(res.telemetry.to_dict(), fh)
        print(f"wrote {args.telemetry_out}")
    _print_telemetry(res.telemetry, label=scn.label())
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json / telemetry JSON files to summarise")
    ap.add_argument("--trace", metavar="PATH",
                    help="simulate (ref kernel) and write the Perfetto "
                         "Chrome trace JSON here")
    ap.add_argument("--telemetry", dest="telemetry_out", metavar="PATH",
                    help="with --trace: also dump the run's telemetry JSON")
    ap.add_argument("--validate", metavar="PATH",
                    help="schema-check an existing Chrome trace JSON")
    ap.add_argument("--apps", nargs="+", default=["wifi_tx"])
    ap.add_argument("--scheduler", default="etf")
    ap.add_argument("--governor", default="ondemand")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="injection rate, jobs/ms")
    ap.add_argument("--jobs", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    status = 0
    for path in args.files:
        _report_file(path)
    if args.validate:
        with open(args.validate) as fh:
            errs = validate_chrome_trace(json.load(fh))
        if errs:
            for e in errs:
                print(f"{args.validate}: {e}")
            status = 1
        else:
            print(f"{args.validate}: valid Chrome trace")
    if args.trace:
        status = max(status, _run_and_trace(args))
    elif args.telemetry_out:
        ap.error("--telemetry requires --trace (it dumps the traced run)")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
