"""Per-sampling-window telemetry timelines (DESIGN.md §11).

A :class:`Telemetry` holds ``(W, C)`` arrays — one row per sampling window,
one column per frequency domain (cluster) — of the quantities the DTPM loop
integrates: OPP/frequency, utilisation, realised node power and RC node
temperatures.  Both kernels produce it:

* **ref, dynamic governor** — a :class:`TelemetryRecorder` passed to
  ``simulate(..., telemetry=rec)`` records each window in-loop (the exact
  values the governor feedback saw);
* **jax, dynamic governor** — :func:`jax_dtpm_telemetry` replays the kernel's
  ``_window_step`` as a separate jitted ``lax.scan`` against the final
  schedule state, stacking the carry via the scan's ys.  Windows only close
  once no later commit can overlap them (``start ≥ data_ready ≥ epoch ≥
  window end``), so the replay is value-identical to the in-loop carry and
  the ``telemetry=False`` simulation program stays byte-identical;
* **static governors** (both backends) — the same window observables at the
  governor's fixed OPP, via :func:`ref_static_telemetry` (numpy replay) and
  :func:`jax_static_telemetry`.

Sizes: ``W = ceil(makespan / window)`` (windows whose *start* precedes the
makespan — matching both kernels' tail drain); ``C`` is the domain count
(``simkernel_jax.MIN_DOMAINS`` floor, accel fabric last: zero utilisation,
zero frequency).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from ..core import thermal as _thermal
from ..core.dvfs import capped_levels
from ..core.power import active_power, idle_power
from ..core.resources import ResourceDB

TELEMETRY_SCHEMA = "repro.obs/telemetry/v1"

#: default sampling window for *static*-governor telemetry, where no governor
#: window exists to inherit (matches OndemandGovernor's default)
DEFAULT_WINDOW_US = 50.0


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Per-window timelines.  All arrays have ``W`` rows (sampling windows,
    window ``w`` covering ``[w·window_us, (w+1)·window_us)``)."""
    window_us: float
    freq_idx: np.ndarray      # (W, C) i32 — OPP index per domain (post-clamp)
    freq_ghz: np.ndarray      # (W, C) f32 — frequency per domain (0 = accel)
    util: np.ndarray          # (W, C) f32 — CPU utilisation per domain
    power_w: np.ndarray       # (W, 3) f32 — realised power per thermal node
    temps_c: np.ndarray       # (W, 4) f32 — RC node temps at window end

    @property
    def num_windows(self) -> int:
        return int(self.freq_idx.shape[0])

    @property
    def num_domains(self) -> int:
        return int(self.freq_idx.shape[1])

    @property
    def time_us(self) -> np.ndarray:
        """Window-end timestamps, (W,)."""
        return (np.arange(self.num_windows, dtype=np.float64) + 1.0) \
            * self.window_us

    @property
    def peak_temp_c(self) -> float:
        """Peak on-chip (non-board) temperature over the timeline."""
        if self.temps_c.size == 0:
            return float(_thermal.T_AMBIENT_C)
        return float(np.max(self.temps_c[:, :3]))

    @property
    def avg_power_w(self) -> float:
        """Mean total (all-node) power over the timeline."""
        if self.power_w.size == 0:
            return 0.0
        return float(np.mean(np.sum(self.power_w, axis=1)))

    def to_dict(self) -> Dict:
        """JSON-ready form (schema-tagged; inverse of :meth:`from_dict`)."""
        return {
            "schema": TELEMETRY_SCHEMA,
            "window_us": float(self.window_us),
            "freq_idx": self.freq_idx.astype(int).tolist(),
            "freq_ghz": np.asarray(self.freq_ghz, np.float64).tolist(),
            "util": np.asarray(self.util, np.float64).tolist(),
            "power_w": np.asarray(self.power_w, np.float64).tolist(),
            "temps_c": np.asarray(self.temps_c, np.float64).tolist(),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "Telemetry":
        if d.get("schema") != TELEMETRY_SCHEMA:
            raise ValueError(f"not a telemetry dict: schema={d.get('schema')!r}")
        return cls(
            window_us=float(d["window_us"]),
            freq_idx=np.asarray(d["freq_idx"], np.int32),
            freq_ghz=np.asarray(d["freq_ghz"], np.float32),
            util=np.asarray(d["util"], np.float32),
            power_w=np.asarray(d["power_w"], np.float32),
            temps_c=np.asarray(d["temps_c"], np.float32),
        )


class TelemetryRecorder:
    """In-loop per-window recorder for the reference kernel.

    ``simulate(..., telemetry=rec)`` calls :meth:`on_window` once per closed
    sampling window (in order); :meth:`build` assembles the ``(W, C)``
    :class:`Telemetry`, padding domains the SoC doesn't populate (the accel
    fabric column) with zeros.
    """

    def __init__(self, window_us: float):
        self.window_us = float(window_us)
        self._rows: List[Dict] = []

    def on_window(self, w_end_us: float, util: Dict[int, float],
                  freq_ghz: Dict[int, float], freq_idx: Dict[int, int],
                  node_power_w: np.ndarray, temps_c: np.ndarray) -> None:
        self._rows.append(dict(
            w_end_us=float(w_end_us),
            util=dict(util), freq_ghz=dict(freq_ghz),
            freq_idx=dict(freq_idx),
            power_w=np.asarray(node_power_w, np.float32).copy(),
            temps_c=np.asarray(temps_c, np.float32).copy(),
        ))

    def build(self, num_domains: int) -> Telemetry:
        W, C = len(self._rows), int(num_domains)
        freq_idx = np.zeros((W, C), np.int32)
        freq_ghz = np.zeros((W, C), np.float32)
        util = np.zeros((W, C), np.float32)
        power_w = np.zeros((W, _thermal.NUM_NODES), np.float32)
        temps_c = np.full((W, 4), _thermal.T_AMBIENT_C, np.float32)
        for w, row in enumerate(self._rows):
            for c, v in row["util"].items():
                util[w, c] = v
            for c, v in row["freq_ghz"].items():
                freq_ghz[w, c] = v
            for c, v in row["freq_idx"].items():
                freq_idx[w, c] = v
            power_w[w] = row["power_w"]
            temps_c[w] = row["temps_c"]
        return Telemetry(self.window_us, freq_idx, freq_ghz, util,
                         power_w, temps_c)


# --------------------------------------------------------------------------
# Shared sizing / frequency-column helpers
# --------------------------------------------------------------------------

def num_windows_for(makespan_us: float, window_us: float) -> int:
    """Windows whose start precedes the makespan (both kernels' drain)."""
    if makespan_us <= 0.0 or window_us <= 0.0:
        return 0
    return int(math.ceil(makespan_us / window_us - 1e-9))


def _bucket_pow2(n: int) -> int:
    """Next power of two ≥ n — the jit-cache bucket for the window axis, so
    sweeping makespans doesn't recompile the telemetry scan per run."""
    return 1 << max(int(n) - 1, 0).bit_length()


def domain_count(db: ResourceDB) -> int:
    """Frequency-domain count for ``db`` (matches ``build_tables``)."""
    from ..core.simkernel_jax import MIN_DOMAINS
    return max(MIN_DOMAINS, max(pe.cluster for pe in db.pes) + 1)


def static_freq_columns(db: ResourceDB, governor, num_domains: int):
    """``(freq_ghz, freq_idx)`` rows, each ``(C,)``, for a *static* governor:
    one fixed entry per CPU cluster (nearest capped-ladder level), zeros for
    the accel domain.  Constants of the governor — shared by both backends so
    the ref↔jax telemetry comparison is exact by construction."""
    caps = getattr(governor, "freq_caps", None)
    freq_ghz = np.zeros(num_domains, np.float32)
    freq_idx = np.zeros(num_domains, np.int32)
    seen = set()
    for pe in db.pes:
        if not pe.is_cpu or pe.cluster in seen:
            continue
        seen.add(pe.cluster)
        f = governor.initial_freq(pe.pe_type)
        opps = capped_levels(pe.pe_type, caps)
        k = min(range(len(opps)), key=lambda i: abs(opps[i] - f))
        freq_ghz[pe.cluster] = opps[k]
        freq_idx[pe.cluster] = k
    return freq_ghz, freq_idx


# --------------------------------------------------------------------------
# JAX glue — wrap the kernel's jitted telemetry scans
# --------------------------------------------------------------------------

def jax_dtpm_telemetry(tables, gov, out: Dict, app_idx) -> Telemetry:
    """Telemetry for one ``simulate_jax_dtpm`` run.

    ``out`` is the kernel's output dict (needs ``scheduled/start/finish/
    onpe/onopp/makespan_us``).  The window axis is bucketed to the next power
    of two for jit-cache stability and truncated back to the real count.
    """
    import jax.numpy as jnp
    from ..core.simkernel_jax import _telemetry_scan_dtpm

    window = float(gov.sample_window_us)
    W = num_windows_for(float(out["makespan_us"]), window)
    if W == 0:
        C = int(tables.opp_freq.shape[0])
        return _empty(window, C)
    ys = _telemetry_scan_dtpm(tables, gov, jnp.asarray(app_idx, jnp.int32),
                              out["scheduled"], out["start"], out["finish"],
                              out["onpe"], out["onopp"],
                              num_windows=_bucket_pow2(W))
    freq_idx = np.asarray(ys["opp_idx"])[:W]
    opp_freq = np.asarray(tables.opp_freq)                       # (C, K)
    C = opp_freq.shape[0]
    freq_ghz = opp_freq[np.arange(C)[None, :], freq_idx]         # (W, C)
    return Telemetry(window, freq_idx.astype(np.int32),
                     freq_ghz.astype(np.float32),
                     np.asarray(ys["util"])[:W],
                     np.asarray(ys["power_w"])[:W],
                     np.asarray(ys["temps_c"])[:W])


def jax_static_telemetry(db: ResourceDB, governor, tables, out: Dict,
                         app_idx,
                         window_us: Optional[float] = None) -> Telemetry:
    """Telemetry for one static-governor ``simulate_jax`` run: the window
    observables at the tables' fixed OPP; frequency columns are governor
    constants (see :func:`static_freq_columns`)."""
    import jax.numpy as jnp
    from ..core.simkernel_jax import _telemetry_scan_static

    window = float(window_us if window_us is not None
                   else getattr(governor, "sample_window_us", None)
                   or DEFAULT_WINDOW_US)
    C = domain_count(db)
    W = num_windows_for(float(out["makespan_us"]), window)
    if W == 0:
        return _empty(window, C)
    ys = _telemetry_scan_static(tables, jnp.asarray(app_idx, jnp.int32),
                                out["scheduled"], out["start"], out["finish"],
                                out["onpe"], window,
                                num_windows=_bucket_pow2(W), num_domains=C)
    f_ghz, f_idx = static_freq_columns(db, governor, C)
    return Telemetry(window,
                     np.broadcast_to(f_idx, (W, C)).copy(),
                     np.broadcast_to(f_ghz, (W, C)).copy(),
                     np.asarray(ys["util"])[:W],
                     np.asarray(ys["power_w"])[:W],
                     np.asarray(ys["temps_c"])[:W])


def _empty(window_us: float, C: int) -> Telemetry:
    return Telemetry(window_us,
                     np.zeros((0, C), np.int32), np.zeros((0, C), np.float32),
                     np.zeros((0, C), np.float32),
                     np.zeros((0, _thermal.NUM_NODES), np.float32),
                     np.zeros((0, 4), np.float32))


# --------------------------------------------------------------------------
# Reference-kernel static replay (numpy)
# --------------------------------------------------------------------------

def ref_static_telemetry(db: ResourceDB, result, governor,
                         window_us: Optional[float] = None) -> Telemetry:
    """Post-hoc telemetry replay of a static-governor reference run: window
    utilisation/power from the realised schedule (``result.records``), RC
    temperatures integrated in real time (dt = window), frequency columns
    from the governor.  Matches :func:`jax_static_telemetry` on comm-free
    traces (asserted in tests/test_obs.py)."""
    window = float(window_us if window_us is not None
                   else getattr(governor, "sample_window_us", None)
                   or DEFAULT_WINDOW_US)
    C = domain_count(db)
    W = num_windows_for(result.makespan_us, window)
    if W == 0:
        return _empty(window, C)

    node_of_pe = _thermal.cluster_nodes(db)
    cl_cpus = np.zeros(C)
    for pe in db.pes:
        if pe.is_cpu:
            cl_cpus[pe.cluster] += 1.0
    p_idle = np.asarray([idle_power(pe) for pe in db.pes])

    util = np.zeros((W, C), np.float32)
    power_w = np.zeros((W, _thermal.NUM_NODES), np.float32)
    temps_c = np.full((W, 4), _thermal.T_AMBIENT_C, np.float32)
    rc_ab = _thermal.exact_step_matrices(window * 1e-6)
    temps = np.full(4, _thermal.T_AMBIENT_C)
    for w in range(W):
        w0, w1 = w * window, (w + 1) * window
        busy = np.zeros(db.num_pes)
        p = np.zeros(_thermal.NUM_NODES)
        for r in result.records:
            ov = max(0.0, min(r.finish_us, w1) - max(r.start_us, w0))
            if ov <= 0.0:
                continue
            pe = db.pes[r.pe_id]
            busy[r.pe_id] += ov
            p[node_of_pe[r.pe_id]] += active_power(pe, r.freq_ghz) * ov / window
            if pe.is_cpu:
                util[w, pe.cluster] += ov
        util[w] /= np.maximum(window * cl_cpus, 1e-9)
        idle_frac = 1.0 - np.clip(busy / window, 0.0, 1.0)
        for j in range(db.num_pes):
            p[node_of_pe[j]] += p_idle[j] * idle_frac[j]
        temps = _thermal.exact_step(temps, p, *rc_ab)
        power_w[w] = p
        temps_c[w] = temps

    f_ghz, f_idx = static_freq_columns(db, governor, C)
    return Telemetry(window,
                     np.broadcast_to(f_idx, (W, C)).copy(),
                     np.broadcast_to(f_ghz, (W, C)).copy(),
                     util, power_w, temps_c)
