"""repro.dse — batched design-space exploration over the JAX sim kernel.

The paper positions system-level simulation as the enabler for "design space
exploration and dynamic resource management"; this package is that mode:

    space:       DesignSpace / DesignPoint — declarative SoC configurations
                 with grid / random / latin-hypercube enumeration
    batch:       pad + stack per-design SimTables into (D, …) tensors;
                 designs × traces simulated in one vmapped jit
    thermal_jax: lax.scan RC thermal co-simulation -> peak temp per design
    pareto:      non-dominated sorting + crowding distance
    search:      evaluate / successive_halving / pareto_search refinement
    reports:     ASCII/CSV front reports + `python -m repro.dse.reports`

Design sweeps are one axis of the unified ``repro.scenario`` facade:
``sweep(scenario, axes={"design": points, …})`` supersedes calling
``build_design_batch`` + ``simulate_design_batch`` by hand (the latter is
kept as a deprecation shim).
"""
from ..core._deprecation import deprecated_entry_point as _deprecated_entry_point
from .batch import (DesignBatch, build_design_batch, pad_node_map,
                    stack_tables, stack_traces)
from .batch import simulate_design_batch as _simulate_design_batch_impl
from .pareto import (crowding_distance, non_dominated_sort, pareto_mask,
                     pareto_order)
from .reports import format_front, front_csv
from .search import (OBJECTIVES, EvalResult, SearchResult, evaluate,
                     pareto_search, successive_halving)
from .space import AREA_MM2, AXES, DesignPoint, DesignSpace
from .thermal_jax import (binned_power_trace, peak_temperature,
                          peak_temperature_grid, steady_state,
                          transient_trace)


simulate_design_batch = _deprecated_entry_point(
    _simulate_design_batch_impl,
    "repro.scenario.sweep(Scenario(...), axes={'design': ..., ...})")


__all__ = [n for n in dir() if not n.startswith("_")]
