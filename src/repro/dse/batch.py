"""Stack per-design simulation tables into one (D, …) tensor program.

Designs differ in PE count, so every per-design :class:`SimTables` is built
padded to the fleet-wide maximum (``build_tables(pad_pes=…)``) and the padded
tables are stacked leaf-wise into a single pytree whose data fields carry a
leading design axis.  Padding is inert by construction (BIG latency, zero
power — see DESIGN.md §5), so the batched kernel needs **no masking logic**:
``jax.vmap`` over the design axis × the trace axis runs designs × seeds ×
injection rates in one ``jit``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.applications import Application
from ..core.dvfs import Governor
from ..core.jobgen import JobTrace
from ..core.simkernel_jax import SimTables, _simulate, build_tables
from ..core.thermal import NODE_ACCEL, cluster_nodes
from .space import DesignPoint


@dataclasses.dataclass(frozen=True)
class DesignBatch:
    """D stacked designs ready for batched simulation.

    No per-PE mask is stored: padding is inert inside the kernel (DESIGN.md
    §5), and consumers slice per-design outputs with ``points[d].num_pes``.
    """
    points: Tuple[DesignPoint, ...]
    tables: SimTables                 # data fields carry a leading (D, …) axis
    node_of_pe: jnp.ndarray           # (D, P) i32 thermal node per PE slot

    @property
    def num_designs(self) -> int:
        return len(self.points)

    @property
    def dynamic(self) -> bool:
        """True when the tables carry OPP ladders for dynamic DTPM policies."""
        return self.tables.exec_opp is not None


def stack_tables(tables: Sequence[SimTables], host: bool = False) -> SimTables:
    """Leaf-wise stack of identically-shaped SimTables into (D, …) tensors.

    ``host=True`` stacks into numpy leaves instead of device arrays — the
    form the chunked/sharded executor (``scenario.shardexec``) streams from,
    so a grid larger than device memory is never device-resident at once.
    """
    shapes = {(t.t_max, t.num_pes) for t in tables}
    if len(shapes) != 1:
        raise ValueError(f"tables must be padded to one shape, got {shapes}")
    if host:
        return jax.tree_util.tree_map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *tables)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tables)


def pad_node_map(dbs, pad_pes: int) -> jnp.ndarray:
    """(D, P) thermal node per PE slot; padded slots are inert (zero-power)
    and binned to the accel node by convention."""
    nodes = np.full((len(dbs), pad_pes), NODE_ACCEL, dtype=np.int32)
    for i, db in enumerate(dbs):
        nodes[i, :db.num_pes] = cluster_nodes(db)
    return jnp.asarray(nodes)


def build_design_batch(points: Sequence[DesignPoint],
                       apps: Sequence[Application],
                       pad_pes: Optional[int] = None,
                       governor: Optional[Governor] = None) -> DesignBatch:
    """Build + pad + stack the simulation tables for a list of designs.

    By default every design bakes its own frequency-cap (userspace) governor
    — the static-DVFS slice of the space.  Passing a *dynamic* ``governor``
    (the ondemand family) instead builds the OPP-indexed tables the DTPM
    kernel gathers from, with each design's OPP ladder truncated at its
    per-cluster frequency caps — so Pareto search ranks dynamic policies
    under the design's static envelope, not just static caps.
    """
    if not points:
        raise ValueError("empty design list")
    dbs = [p.to_db() for p in points]
    P = max(db.num_pes for db in dbs)
    if pad_pes is not None:
        if pad_pes < P:
            raise ValueError(f"pad_pes={pad_pes} < widest design {P}")
        P = pad_pes
    if governor is not None:
        if not governor.policy().dynamic:
            # a uniform static governor would silently override the
            # per-design frequency caps the sweep contract assumes
            raise ValueError(
                "build_design_batch bakes per-design frequency caps; pass "
                "a dynamic (ondemand-family) governor to add OPP ladders, "
                "or None for the static design-cap tables")
        per_design = [
            build_tables(db, apps, governor=governor, pad_pes=P,
                         freq_caps=p.freq_caps())
            for p, db in zip(points, dbs)]
    else:
        per_design = [build_tables(db, apps, governor=p.governor(), pad_pes=P)
                      for p, db in zip(points, dbs)]
    return DesignBatch(points=tuple(points), tables=stack_tables(per_design),
                       node_of_pe=pad_node_map(dbs, P))


def stack_traces(traces: Sequence[JobTrace]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(S, J) arrival / app-index tensors from S equal-length job traces."""
    lens = {t.num_jobs for t in traces}
    if len(lens) != 1:
        raise ValueError(f"traces must have equal job counts, got {lens}")
    arr = jnp.asarray(np.stack([t.arrival_us for t in traces]), jnp.float32)
    idx = jnp.asarray(np.stack([t.app_index for t in traces]), jnp.int32)
    return arr, idx


@functools.partial(jax.jit, static_argnames=("policy", "num_jobs"))
def _simulate_grid(tables: SimTables, policy: str, num_jobs: int,
                   arrival: jnp.ndarray, app_idx: jnp.ndarray):
    """(D designs) × (S traces) simulations as one tensor program."""
    per_trace = jax.vmap(
        lambda tb, a, i: _simulate(tb, policy, num_jobs, a, i),
        in_axes=(None, 0, 0))                      # map traces, share design
    per_design = jax.vmap(per_trace, in_axes=(0, None, None))
    return per_design(tables, arrival, app_idx)


@functools.partial(jax.jit,
                   static_argnames=("policy", "num_jobs", "scan_steps"))
def _simulate_grid_faults(tables: SimTables, policy: str, num_jobs: int,
                          arrival: jnp.ndarray, app_idx: jnp.ndarray,
                          fplans: jnp.ndarray, scan_steps: int):
    """(F fault plans) × (D designs) × (S traces) fail-stop simulations.

    ``fplans``: (F, P) f32 per-PE fail times (``+inf`` = never fails, see
    ``repro.scenario.faults``); ``scan_steps`` is the static epoch budget
    covering the widest lane's rollbacks (DESIGN.md §14).  The fault axis is
    outermost so the design axis stays streamable (``scenario.shardexec``).
    """
    per_trace = jax.vmap(
        lambda tb, a, i, fp: _simulate(tb, policy, num_jobs, a, i, fp,
                                       scan_steps=scan_steps),
        in_axes=(None, 0, 0, None))
    per_design = jax.vmap(per_trace, in_axes=(0, None, None, None))
    per_fault = jax.vmap(per_design, in_axes=(None, None, None, 0))
    return per_fault(tables, arrival, app_idx, fplans)


def simulate_design_batch(batch: DesignBatch, policy: str,
                          arrival: jnp.ndarray, app_idx: jnp.ndarray) -> Dict:
    """Run all designs × traces in one jitted call.

    ``arrival``/``app_idx``: (S, J) as from :func:`stack_traces`.  Every entry
    of the returned dict gains leading (D, S) axes over ``simulate_jax``'s
    output — e.g. ``avg_job_latency_us`` is (D, S), ``busy_per_pe_us`` is
    (D, S, P).
    """
    arrival = jnp.asarray(arrival, jnp.float32)
    app_idx = jnp.asarray(app_idx, jnp.int32)
    if arrival.ndim != 2:
        raise ValueError("arrival must be (num_traces, num_jobs)")
    return _simulate_grid(batch.tables, policy, int(arrival.shape[1]),
                          arrival, app_idx)
