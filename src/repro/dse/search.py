"""Batched design-space evaluation + Pareto refinement loop.

``evaluate`` turns a list of design points into (latency, energy, peak-temp)
objectives with ONE jitted tensor program per scheduler policy.  It is a
thin delegate over the ``repro.scenario`` facade: the design list becomes a
``sweep(scenario, axes={"design": …, "trace": …})`` whose fused
schedule-plus-thermal grid program lives in ``repro.scenario.sweep``.

``pareto_search`` is the refinement loop (DS3-journal style DSE): seed a
latin-hypercube batch, keep a cross-round archive, and re-seed each next
batch from the current non-dominated front's neighborhood (one-axis moves)
plus random immigrants.  ``successive_halving`` optionally triages each
batch on a trace subset before paying for the full evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.applications import Application
from ..core.jobgen import JobTrace
from ..obs import metrics as _metrics
from .batch import DesignBatch, build_design_batch
from .pareto import pareto_mask, pareto_order
from .space import DesignPoint, DesignSpace

OBJECTIVES = ("avg_latency_us", "energy_j", "peak_temp_c")
DEGRADED_OBJECTIVE = "degraded_latency_us"


def _lane_fires(fault_set) -> bool:
    """True when a fault-lane value contains at least one firing event."""
    from ..scenario.faults import normalize_failures
    return any(not f.is_noop for f in normalize_failures(fault_set))


@dataclasses.dataclass
class EvalResult:
    """Objectives for D designs, averaged/maxed over S traces.

    When ``evaluate(faults=...)`` swept fail-stop lanes, the three
    ``degraded_*`` fields carry the resilience metric (DESIGN.md §14):
    per-design worst case over the fault lanes of the trace-mean
    latency/energy — how gracefully the design degrades when it loses PEs.
    """
    points: Tuple[DesignPoint, ...]
    avg_latency_us: np.ndarray        # (D,) mean over traces
    energy_j: np.ndarray              # (D,) mean over traces
    peak_temp_c: np.ndarray           # (D,) max over traces
    latency_per_trace_us: np.ndarray     # (D, S)
    energy_per_trace_j: np.ndarray      # (D, S)
    temp_per_trace_c: np.ndarray        # (D, S)
    degraded_latency_us: Optional[np.ndarray] = None   # (D,) worst fault lane
    degraded_energy_j: Optional[np.ndarray] = None     # (D,) worst fault lane
    latency_per_fault_us: Optional[np.ndarray] = None  # (F, D) trace means

    @property
    def num_designs(self) -> int:
        return len(self.points)

    def objectives(self) -> np.ndarray:
        """(D, 3) cost matrix (all minimised) in OBJECTIVES order — (D, 4)
        with the degraded-latency resilience column when faults were swept."""
        cols = [self.avg_latency_us, self.energy_j, self.peak_temp_c]
        if self.degraded_latency_us is not None:
            cols.append(self.degraded_latency_us)
        return np.stack(cols, axis=1)

    def front_mask(self) -> np.ndarray:
        return pareto_mask(self.objectives())


def _cat_opt(x, y, axis: int = 0):
    return (np.concatenate([x, y], axis=axis)
            if x is not None and y is not None else None)


def _concat(a: "EvalResult", b: "EvalResult") -> "EvalResult":
    return EvalResult(
        points=a.points + b.points,
        avg_latency_us=np.concatenate([a.avg_latency_us, b.avg_latency_us]),
        energy_j=np.concatenate([a.energy_j, b.energy_j]),
        peak_temp_c=np.concatenate([a.peak_temp_c, b.peak_temp_c]),
        latency_per_trace_us=np.concatenate([a.latency_per_trace_us,
                                          b.latency_per_trace_us]),
        energy_per_trace_j=np.concatenate([a.energy_per_trace_j,
                                         b.energy_per_trace_j]),
        temp_per_trace_c=np.concatenate([a.temp_per_trace_c, b.temp_per_trace_c]),
        degraded_latency_us=_cat_opt(a.degraded_latency_us,
                                     b.degraded_latency_us),
        degraded_energy_j=_cat_opt(a.degraded_energy_j, b.degraded_energy_j),
        latency_per_fault_us=_cat_opt(a.latency_per_fault_us,
                                      b.latency_per_fault_us, axis=1))


def evaluate(points: Sequence[DesignPoint], apps: Sequence[Application],
             traces: Sequence[JobTrace], policy: str = "etf",
             thermal_bins: int = 32, thermal_repeats: int = 3,
             pad_pes: Optional[int] = None,
             batch: Optional[DesignBatch] = None,
             governor: str = "design",
             governor_params: Tuple[Tuple[str, float], ...] = (),
             chunk: Optional[int] = None,
             shard: Optional[bool] = None,
             faults: Optional[Sequence] = None) -> EvalResult:
    """Evaluate D designs × S traces in one vmapped/jitted call per policy.

    ``pad_pes`` fixes the padded PE width so successive calls with different
    design mixes reuse the same compiled program (jit cache hit).

    ``chunk``/``shard`` delegate to the sweep's sharded/chunked lane
    executor (``scenario.shardexec``, DESIGN.md §13): the design lanes are
    split across local devices and/or streamed in fixed-shape chunks with
    bounded device memory — bit-for-bit equal to the plain batched call, so
    ``pareto_search``/``successive_halving`` pass them through ``eval_kw``
    unchanged.

    ``governor`` widens the DVFS axis of the search: the default ``"design"``
    pins each design's static frequency caps; a *dynamic* governor
    (``"ondemand"`` / ``"throttle"``, parameterised via ``governor_params``)
    ranks closed-loop DTPM policies instead — the stacked tables gain the
    OPP dimension (each design's ladder truncated at its caps) and peak
    temperature comes from the kernel's inline RC loop, so
    ``thermal_bins``/``thermal_repeats`` only shape the static path.

    ``faults`` adds a resilience objective: a sequence of fail-stop fault
    sets (e.g. ``repro.scenario.pe_loss_faults(range(4), k=1)`` — every
    1-PE-loss of the first cluster) swept as one extra vmapped lane axis
    through the same compiled program per policy.  The degraded-mode
    latency/energy (worst case over the fault lanes of the trace means)
    land on ``EvalResult.degraded_*``, and ``objectives()`` grows the
    degraded-latency column so the Pareto front trades peak performance
    against graceful degradation (DESIGN.md §14).
    """
    # lazy import: repro.scenario builds on repro.dse, not the reverse
    from ..scenario import Scenario, ThermalSpec
    from ..scenario.sweep import sweep

    governor_params = tuple(governor_params)
    base = Scenario(apps=tuple(apps), scheduler=policy, governor=governor,
                    governor_params=governor_params,
                    thermal=ThermalSpec(bins=thermal_bins,
                                        repeats=thermal_repeats))
    dynamic = base.make_policy().dynamic
    if dynamic and "thermal_dt_s" not in dict(governor_params):
        # real-time RC integration keeps millisecond traces at ambient,
        # collapsing the temperature objective to float noise — default the
        # thermal dilation to the throttle governor's 50 ms so peak_temp_c
        # actually separates designs (override via governor_params)
        governor_params += (("thermal_dt_s", 0.05),)
        base = dataclasses.replace(base, governor_params=governor_params)
    if not dynamic and governor != "design":
        raise ValueError(
            "static DVFS points are the design axis itself — use "
            "governor='design' (per-design frequency caps) or a dynamic "
            "governor ('ondemand'/'throttle') for DTPM-policy ranking")
    if batch is None:
        batch = build_design_batch(
            points, apps, pad_pes=pad_pes,
            governor=base.make_governor() if dynamic else None)
    elif tuple(points) != batch.points:
        raise ValueError("points does not match batch.points — pass the same "
                         "design list the batch was built from")
    if batch.dynamic != dynamic:
        raise ValueError(
            "design batch and governor disagree: rebuild the batch with "
            "build_design_batch(..., governor=...) matching the governor")
    axes: Dict = {"design": list(batch.points), "trace": list(traces)}
    if faults is not None:
        axes["faults"] = list(faults)
    sr = sweep(base, axes=axes,
               backend="jax", design_batch=batch, chunk=chunk, shard=shard)
    lat, energy, temps = sr.avg_latency_us, sr.energy_j, sr.peak_temp_c
    deg_kw: Dict = {}
    if faults is not None:
        # (D, S, F) per the axes-dict order; worst fault lane of trace means
        lat_f = np.moveaxis(lat, 2, 0)            # (F, D, S)
        en_f = np.moveaxis(energy, 2, 0)
        deg_kw = dict(degraded_latency_us=lat_f.mean(axis=2).max(axis=0),
                      degraded_energy_j=en_f.mean(axis=2).max(axis=0),
                      latency_per_fault_us=lat_f.mean(axis=2))
        # the nominal objectives stay the fault-free ones: the first
        # all-no-op lane if present, else the first lane
        noop = next((i for i, fs in enumerate(axes["faults"])
                     if not _lane_fires(fs)), 0)
        lat, energy, temps = lat[:, :, noop], energy[:, :, noop], \
            temps[:, :, noop]
    return EvalResult(points=tuple(batch.points),
                      avg_latency_us=lat.mean(axis=1),
                      energy_j=energy.mean(axis=1),
                      peak_temp_c=temps.max(axis=1),
                      latency_per_trace_us=lat, energy_per_trace_j=energy,
                      temp_per_trace_c=temps, **deg_kw)


def successive_halving(points: Sequence[DesignPoint],
                       apps: Sequence[Application],
                       traces: Sequence[JobTrace], policy: str = "etf",
                       eta: int = 2, min_survivors: int = 4,
                       pad_pes: Optional[int] = None,
                       **eval_kw) -> EvalResult:
    """Triaged evaluation: rank all candidates on ONE trace, keep the best
    1/eta (by Pareto order) for the full-trace evaluation.  Returns the full
    result for survivors only — a cheap filter in front of ``evaluate``."""
    if len(traces) <= 1 or len(points) <= min_survivors:
        return evaluate(points, apps, traces, policy, pad_pes=pad_pes,
                        **eval_kw)
    cheap = evaluate(points, apps, traces[:1], policy, pad_pes=pad_pes,
                     **eval_kw)
    keep = max(min_survivors, len(points) // eta)
    order = pareto_order(cheap.objectives())[:keep]
    survivors = [points[i] for i in sorted(order)]
    return evaluate(survivors, apps, traces, policy, pad_pes=pad_pes,
                    **eval_kw)


@dataclasses.dataclass
class SearchResult:
    archive: EvalResult               # every design ever fully evaluated
    front: np.ndarray                 # bool mask over the archive
    rounds: List[Dict]                # per-round stats (evaluated, front size)

    def front_points(self) -> List[Tuple[DesignPoint, np.ndarray]]:
        obj = self.archive.objectives()
        idx = [i for i in np.flatnonzero(self.front)]
        order = pareto_order(obj[self.front])
        return [(self.archive.points[idx[i]], obj[idx[i]]) for i in order]


def pareto_search(space: DesignSpace, apps: Sequence[Application],
                  traces: Sequence[JobTrace], policy: str = "etf",
                  rounds: int = 4, batch_size: int = 32, seed: int = 0,
                  budget_mm2: Optional[float] = None, halving: bool = False,
                  pad_pes: Optional[int] = None, **eval_kw) -> SearchResult:
    """Evolutionary Pareto refinement over ``space``.

    Round 0 seeds a latin-hypercube batch; each later round mutates the
    current front (all one-axis neighbour moves, crowding-ordered) and tops
    up with unseen random immigrants, so the batch stays ``batch_size`` wide
    and every vmapped evaluation is full.  Deterministic for a given seed.
    """
    if pad_pes is None:
        # widest possible design in this space -> one compiled program
        pad_pes = (max(space.num_big) + max(space.num_little)
                   + max(space.num_scr) + max(space.num_fft)
                   + max(space.num_vit))
    seen: set = set()
    archive: Optional[EvalResult] = None
    round_stats: List[Dict] = []
    candidates = space.sample_lhs(batch_size, seed=seed,
                                  budget_mm2=budget_mm2)
    if not candidates:
        raise ValueError(
            f"no feasible designs in the space under budget_mm2={budget_mm2}")
    for rnd in range(rounds):
        candidates = [p for p in candidates if p not in seen]
        if not candidates:
            break
        seen.update(candidates)
        t_round = _metrics.timer("dse.pareto_search.round")
        with t_round:
            ev = (successive_halving(candidates, apps, traces, policy,
                                     pad_pes=pad_pes, **eval_kw) if halving
                  else evaluate(candidates, apps, traces, policy,
                                pad_pes=pad_pes, **eval_kw))
        _metrics.counter("dse.search.designs_evaluated").inc(ev.num_designs)
        archive = ev if archive is None else _concat(archive, ev)
        front = archive.front_mask()
        round_stats.append(dict(round=rnd, evaluated=ev.num_designs,
                                archive=archive.num_designs,
                                front=int(front.sum()),
                                wall_s=t_round.last_s))
        if rnd == rounds - 1:
            break
        # next generation: neighbourhood of the front, best-crowding first
        front_idx = np.flatnonzero(front)
        obj = archive.objectives()
        ordered = pareto_order(obj[front])
        nxt: List[DesignPoint] = []
        for i in ordered:
            for q in space.neighbors(archive.points[front_idx[i]]):
                if q not in seen and q not in nxt:
                    if budget_mm2 is None or q.area_mm2 <= budget_mm2:
                        nxt.append(q)
        # reserve at least a quarter of the batch for random immigrants
        nxt = nxt[:max(1, batch_size - max(1, batch_size // 4))]
        immigrants = space.sample_random(
            batch_size - len(nxt), seed=seed + 1000 + rnd,
            budget_mm2=budget_mm2, exclude=list(seen) + nxt)
        candidates = nxt + immigrants
    return SearchResult(archive=archive, front=archive.front_mask(),
                        rounds=round_stats)
