"""Declarative SoC design space — the "what to explore" half of DSE.

A :class:`DesignPoint` is one concrete SoC: big/LITTLE core counts,
accelerator counts per type, per-cluster frequency caps and an interconnect
cross-cluster penalty.  A :class:`DesignSpace` is the cartesian hull those
points are drawn from, with three enumeration modes:

* ``grid()``            — exhaustive, deterministic product order;
* ``sample_random(n)``  — uniform without replacement (seeded);
* ``sample_lhs(n)``     — latin-hypercube over the discrete axes (seeded),
                          the default for search seeding: n points that
                          stratify every axis instead of clumping.

Budget-constrained sweeps (Lumos-style): each point carries an ``area_mm2``
proxy so ``grid(budget_mm2=...)`` walks only the affordable region.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.dvfs import UserspaceGovernor
from ..core.resources import (CPU_BIG, CPU_LITTLE, OPP_TABLE, CommModel,
                              ResourceDB, make_soc)

# Die-area proxy (mm²) per PE instance — 28nm-class planning numbers, used
# only to rank/bound designs, never in the timing model itself.
AREA_MM2 = {
    "big": 4.5,        # Cortex-A15 class core + L1
    "little": 0.45,    # Cortex-A7 class core + L1
    "scr": 0.30,       # scrambler-encoder accelerator
    "fft": 1.20,       # FFT accelerator
    "vit": 1.00,       # Viterbi accelerator
}

BIG_FREQS = tuple(f for f, _ in OPP_TABLE[CPU_BIG])
LITTLE_FREQS = tuple(f for f, _ in OPP_TABLE[CPU_LITTLE])


@dataclasses.dataclass(frozen=True, order=True)
class DesignPoint:
    """One concrete SoC configuration (hashable, totally ordered)."""
    num_big: int = 4
    num_little: int = 4
    num_scr: int = 2
    num_fft: int = 4
    num_vit: int = 0
    big_freq_ghz: float = BIG_FREQS[-1]
    little_freq_ghz: float = LITTLE_FREQS[-1]
    cross_cluster_penalty: float = 2.0

    @property
    def num_pes(self) -> int:
        return (self.num_big + self.num_little + self.num_scr
                + self.num_fft + self.num_vit)

    @property
    def area_mm2(self) -> float:
        return (self.num_big * AREA_MM2["big"]
                + self.num_little * AREA_MM2["little"]
                + self.num_scr * AREA_MM2["scr"]
                + self.num_fft * AREA_MM2["fft"]
                + self.num_vit * AREA_MM2["vit"])

    def is_valid(self) -> bool:
        """A design must keep at least one CPU (several tasks are CPU-only)."""
        return self.num_pes > 0 and (self.num_big + self.num_little) > 0

    def label(self) -> str:
        return (f"b{self.num_big}L{self.num_little}s{self.num_scr}"
                f"f{self.num_fft}v{self.num_vit}"
                f"@{self.big_freq_ghz:g}/{self.little_freq_ghz:g}"
                f"x{self.cross_cluster_penalty:g}")

    def to_db(self) -> ResourceDB:
        comm = CommModel(cross_cluster_penalty=self.cross_cluster_penalty)
        return make_soc(self.num_big, self.num_little, self.num_scr,
                        self.num_fft, self.num_vit, comm=comm)

    def freq_caps(self) -> Dict[str, float]:
        """Per-type frequency caps — the design's hardware envelope, shared
        by the static userspace governor and the dynamic governors' OPP
        ladder truncation (one source for both backends)."""
        return {CPU_BIG: self.big_freq_ghz, CPU_LITTLE: self.little_freq_ghz}

    def governor(self) -> UserspaceGovernor:
        """Frequency caps as a userspace governor (static DVFS point)."""
        return UserspaceGovernor(self.freq_caps())


# Axis order is part of the public contract: grid() enumerates in this order
# and sampling strata are drawn per axis in this order — deterministic.
AXES: Tuple[str, ...] = (
    "num_big", "num_little", "num_scr", "num_fft", "num_vit",
    "big_freq_ghz", "little_freq_ghz", "cross_cluster_penalty",
)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Cartesian design space: allowed values per axis."""
    num_big: Tuple[int, ...] = (0, 1, 2, 4)
    num_little: Tuple[int, ...] = (0, 2, 4, 8)
    num_scr: Tuple[int, ...] = (0, 1, 2)
    num_fft: Tuple[int, ...] = (0, 2, 4)
    num_vit: Tuple[int, ...] = (0, 1)
    big_freq_ghz: Tuple[float, ...] = (1.4, 2.0)
    little_freq_ghz: Tuple[float, ...] = (1.0, 1.4)
    cross_cluster_penalty: Tuple[float, ...] = (2.0,)

    def axis_values(self) -> Dict[str, Tuple]:
        return {a: tuple(getattr(self, a)) for a in AXES}

    @property
    def size(self) -> int:
        """Cardinality of the hull (before validity/budget filtering)."""
        n = 1
        for a in AXES:
            n *= len(getattr(self, a))
        return n

    def _point(self, values: Sequence) -> DesignPoint:
        return DesignPoint(**dict(zip(AXES, values)))

    def contains(self, p: DesignPoint) -> bool:
        return all(getattr(p, a) in getattr(self, a) for a in AXES)

    # -- enumeration -------------------------------------------------------
    def grid(self, budget_mm2: Optional[float] = None) -> List[DesignPoint]:
        """Exhaustive deterministic enumeration (product order over AXES)."""
        out = []
        for values in itertools.product(*(getattr(self, a) for a in AXES)):
            p = self._point(values)
            if not p.is_valid():
                continue
            if budget_mm2 is not None and p.area_mm2 > budget_mm2:
                continue
            out.append(p)
        return out

    def sample_random(self, n: int, seed: int = 0,
                      budget_mm2: Optional[float] = None,
                      exclude: Sequence[DesignPoint] = ()) -> List[DesignPoint]:
        """n distinct valid points, uniform over the hull (seeded)."""
        rng = np.random.default_rng(seed)
        seen = set(exclude)
        out: List[DesignPoint] = []
        sizes = [len(getattr(self, a)) for a in AXES]
        for _ in range(max(64, 50 * n)):
            if len(out) >= n:
                break
            idx = [int(rng.integers(k)) for k in sizes]
            p = self._point([getattr(self, a)[i] for a, i in zip(AXES, idx)])
            if not p.is_valid() or p in seen:
                continue
            if budget_mm2 is not None and p.area_mm2 > budget_mm2:
                continue
            seen.add(p)
            out.append(p)
        if len(out) < n:
            # draw budget exhausted (tiny feasible region): fall back to the
            # exhaustive grid so the "min(n, feasible) points" contract holds
            pool = [p for p in self.grid(budget_mm2=budget_mm2)
                    if p not in seen]
            order = rng.permutation(len(pool))
            out += [pool[i] for i in order[:n - len(out)]]
        return out

    def sample_lhs(self, n: int, seed: int = 0,
                   budget_mm2: Optional[float] = None) -> List[DesignPoint]:
        """Latin-hypercube sample: every axis stratified into n bins, bins
        permuted independently per axis, then mapped onto the discrete values.
        Invalid/duplicate/over-budget draws are topped up with
        ``sample_random`` so exactly ``min(n, feasible)`` points return."""
        rng = np.random.default_rng(seed)
        cols = []
        for a in AXES:
            vals = getattr(self, a)
            strata = rng.permutation(n)                    # one bin per sample
            cols.append([vals[int(s * len(vals) // n)] for s in strata])
        seen = set()
        out: List[DesignPoint] = []
        for row in zip(*cols):
            p = self._point(row)
            if not p.is_valid() or p in seen:
                continue
            if budget_mm2 is not None and p.area_mm2 > budget_mm2:
                continue
            seen.add(p)
            out.append(p)
        if len(out) < n:
            out += self.sample_random(n - len(out), seed=seed + 1,
                                      budget_mm2=budget_mm2, exclude=out)
        return out

    # -- local moves (used by the evolutionary refinement loop) ------------
    def neighbors(self, p: DesignPoint) -> List[DesignPoint]:
        """All one-axis ±1-step moves from ``p`` that stay in the space."""
        out = []
        for a in AXES:
            vals = getattr(self, a)
            try:
                i = vals.index(getattr(p, a))
            except ValueError:
                continue
            for j in (i - 1, i + 1):
                if 0 <= j < len(vals):
                    q = dataclasses.replace(p, **{a: vals[j]})
                    if q.is_valid():
                        out.append(q)
        return out
