"""Batched RC thermal co-simulation (JAX port of ``repro.core.thermal``).

Same lumped network — nodes [big, LITTLE, accel fabric] coupled through a
board node to ambient — integrated with forward Euler under ``lax.scan`` so
peak temperature evaluates for every (design, trace) pair inside the same
``jit`` as the schedule simulation.

Pipeline:
  1. ``binned_power_trace``   — time-bin each realised schedule
     (start/finish/onpe from the sim kernel) into a (K, 3) per-node power
     trace: active power while a PE runs, idle leakage otherwise.
  2. ``peak_temperature``     — treat the trace as one period of a sustained
     (streaming) workload: warm-start from the analytical steady state of
     the period-mean power, then scan a few periods at the real time step to
     capture the intra-period ripple.  Linear RC + period ≪ thermal time
     constants ⇒ this is the converged periodic response, at O(K·repeats)
     cost instead of integrating seconds of transient.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import thermal as _ref

T_AMBIENT_C = jnp.float32(_ref.T_AMBIENT_C)
R_TO_BOARD = jnp.asarray(_ref.R_TO_BOARD, jnp.float32)     # (3,) K/W
C_NODE = jnp.asarray(_ref.C_NODE, jnp.float32)             # (3,) J/K
R_BOARD_AMB = jnp.float32(_ref.R_BOARD_AMB)
C_BOARD = jnp.float32(_ref.C_BOARD)


def steady_state(power_w: jnp.ndarray) -> jnp.ndarray:
    """Analytical steady state for constant (3,) node power -> (4,) temps."""
    tb = T_AMBIENT_C + R_BOARD_AMB * jnp.sum(power_w)
    return jnp.concatenate([tb + R_TO_BOARD * power_w, tb[None]])


def euler_step(temps: jnp.ndarray, power_w: jnp.ndarray,
               dt_s: jnp.ndarray) -> jnp.ndarray:
    """One forward-Euler step on the (4,) [nodes..., board] state."""
    t_node, t_board = temps[:3], temps[3]
    flow = (t_node - t_board) / R_TO_BOARD
    t_node = t_node + dt_s / C_NODE * (power_w - flow)
    t_board = t_board + dt_s / C_BOARD * (
        jnp.sum(flow) - (t_board - T_AMBIENT_C) / R_BOARD_AMB)
    return jnp.concatenate([t_node, t_board[None]])


def transient_trace(power_trace_w: jnp.ndarray, dt_s,
                    init: jnp.ndarray | None = None) -> jnp.ndarray:
    """Integrate a (K, 3) power trace from ``init`` (default ambient).

    Returns (K, 4) temperatures — the ``lax.scan`` twin of
    ``repro.core.thermal.simulate_trace``.
    """
    t0 = (jnp.full((4,), T_AMBIENT_C) if init is None
          else jnp.asarray(init, jnp.float32))
    dt = jnp.float32(dt_s)

    def step(temps, p):
        nxt = euler_step(temps, p, dt)
        return nxt, nxt

    _, out = jax.lax.scan(step, t0, jnp.asarray(power_trace_w, jnp.float32))
    return out


def binned_power_trace(start_us: jnp.ndarray, finish_us: jnp.ndarray,
                       onpe: jnp.ndarray, valid: jnp.ndarray,
                       node_of_pe: jnp.ndarray, power_active: jnp.ndarray,
                       power_idle: jnp.ndarray, makespan_us: jnp.ndarray,
                       bins: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-node power trace of one realised schedule.

    Args (one simulation): start/finish/valid (J, T); onpe (J, T) i32;
    node_of_pe (P,) i32; power_active/power_idle (P,).
    Returns ((bins, 3) node power in W, scalar bin width in seconds).
    """
    P = power_active.shape[0]
    dt_us = jnp.maximum(makespan_us, 1e-6) / bins
    edges = jnp.arange(bins, dtype=jnp.float32) * dt_us            # (K,)
    s = jnp.where(valid, start_us, 0.0)[..., None]                 # (J,T,1)
    f = jnp.where(valid, finish_us, 0.0)[..., None]
    overlap = (jnp.minimum(f, edges + dt_us)
               - jnp.maximum(s, edges))                            # (J,T,K)
    overlap = jnp.clip(overlap, 0.0, dt_us)
    pe_onehot = jax.nn.one_hot(onpe, P, dtype=jnp.float32)         # (J,T,P)
    pe_onehot = pe_onehot * jnp.where(valid, 1.0, 0.0)[..., None]
    busy = jnp.einsum("jtk,jtp->kp", overlap, pe_onehot)           # (K,P)
    util = jnp.clip(busy / dt_us, 0.0, 1.0)
    power_pe = power_active * util + power_idle * (1.0 - util)     # (K,P)
    node_onehot = jax.nn.one_hot(node_of_pe, _ref.NUM_NODES,
                                 dtype=jnp.float32)                # (P,3)
    return power_pe @ node_onehot, dt_us * 1e-6


def rc_state_matrix() -> jnp.ndarray:
    """(4, 4) continuous-time state matrix M of the linear RC network —
    the jnp view of :func:`repro.core.thermal.rc_state_matrix` (one
    definition shared with the reference integrator and the DTPM kernels)."""
    return jnp.asarray(_ref.rc_state_matrix(), jnp.float32)


def exact_step_matrices(dt_s) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(A, B) of the exact piecewise-constant update x' = A x + B u — the
    ``lax``-traceable twin of ``repro.core.thermal.exact_step_matrices``
    (one definition, shared with the DTPM kernel's inline thermal loop)."""
    return _ref.exact_step_matrices_jax(dt_s)


def peak_temperature(power_trace_w: jnp.ndarray, dt_s: jnp.ndarray,
                     repeats: int = 3) -> jnp.ndarray:
    """Peak on-chip temperature under a sustained periodic (K, 3) trace.

    Power is constant within a bin, so each bin advances by the *exact*
    linear-RC solution  x' = e^{M·dt} x + M⁻¹(e^{M·dt} − I) u  — e^{M·dt}
    built per trace from the host-precomputed spectral form (DESIGN.md §6;
    batch-width-independent rounding, unlike a batched ``expm``),
    unconditionally stable for any bin width (unlike forward Euler, which
    diverges once dt exceeds ~2·min(RC); bins are makespan/K and the
    makespan is workload-dependent, so no dt bound can be assumed here).
    """
    power_trace_w = jnp.asarray(power_trace_w, jnp.float32)
    A, B = exact_step_matrices(dt_s)
    amb_drive = T_AMBIENT_C / (R_BOARD_AMB * C_BOARD)
    t0 = steady_state(jnp.mean(power_trace_w, axis=0))
    K = power_trace_w.shape[0]
    idx = jnp.arange(K * repeats, dtype=jnp.int32) % K

    def step(temps, k):
        u = jnp.concatenate([power_trace_w[k] / C_NODE, amb_drive[None]])
        nxt = A @ temps + B @ u
        return nxt, jnp.max(nxt[:3])

    _, peaks = jax.lax.scan(step, t0, idx)
    return jnp.maximum(jnp.max(peaks), jnp.max(t0[:3]))


@functools.partial(jax.jit, static_argnames=("bins", "repeats"))
def peak_temperature_grid(sim_out: Dict, node_of_pe: jnp.ndarray,
                          power_active: jnp.ndarray, power_idle: jnp.ndarray,
                          bins: int = 32, repeats: int = 3) -> jnp.ndarray:
    """(D, S) peak temperatures from batched simulation output.

    ``sim_out`` is the dict from ``simulate_design_batch`` (leading (D, S)
    axes); ``node_of_pe``/``power_active``/``power_idle`` are (D, P).
    """
    def one(start, finish, onpe, scheduled, makespan, nodes, p_act, p_idle):
        trace, dt = binned_power_trace(start, finish, onpe, scheduled,
                                       nodes, p_act, p_idle, makespan, bins)
        return peak_temperature(trace, dt, repeats=repeats)

    per_trace = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, None, None, None))
    per_design = jax.vmap(per_trace, in_axes=(0, 0, 0, 0, 0, 0, 0, 0))
    return per_design(sim_out["start"], sim_out["finish"], sim_out["onpe"],
                      sim_out["scheduled"], sim_out["makespan_us"],
                      node_of_pe, power_active, power_idle)
