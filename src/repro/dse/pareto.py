"""Non-dominated sorting over design objectives (all minimised).

Pure numpy, O(N²) pairwise — design archives are thousands of points at
most, so clarity beats asymptotics.  Duplicated objective vectors do not
dominate each other: both stay on the front (distinct designs can tie).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def _pairwise_dominance(costs: np.ndarray) -> np.ndarray:
    """(N, N) bool: entry [i, j] = point i dominates point j."""
    c = np.asarray(costs, dtype=np.float64)
    if c.ndim != 2:
        raise ValueError("costs must be (num_points, num_objectives)")
    le = np.all(c[:, None, :] <= c[None, :, :], axis=-1)
    lt = np.any(c[:, None, :] < c[None, :, :], axis=-1)
    return le & lt


def pareto_mask(costs: np.ndarray) -> np.ndarray:
    """(N,) bool — True where no other point dominates (the Pareto front)."""
    return ~_pairwise_dominance(costs).any(axis=0)


def non_dominated_sort(costs: np.ndarray) -> np.ndarray:
    """(N,) int ranks: 0 = Pareto front, 1 = front once rank-0 removed, …"""
    dom = _pairwise_dominance(costs)
    n = dom.shape[0]
    ranks = np.full(n, -1, dtype=np.int64)
    remaining = np.ones(n, dtype=bool)
    rank = 0
    while remaining.any():
        # dominated only counts dominators still in play
        front = remaining & ~(dom & remaining[:, None]).any(axis=0)
        ranks[front] = rank
        remaining &= ~front
        rank += 1
    return ranks


def crowding_distance(costs: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance (within one front): boundary points get inf,
    interior points the normalised perimeter of their objective-space hole."""
    c = np.asarray(costs, dtype=np.float64)
    n, m = c.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(c[:, k], kind="stable")
        span = c[order[-1], k] - c[order[0], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        dist[order[1:-1]] += (c[order[2:], k] - c[order[:-2], k]) / span
    return dist


def pareto_order(costs: np.ndarray) -> np.ndarray:
    """Indices sorted by (rank asc, crowding desc) — selection order for
    evolutionary refinement and for pretty-printing fronts."""
    ranks = non_dominated_sort(costs)
    crowd = np.zeros(len(ranks))
    for r in np.unique(ranks):
        sel = ranks == r
        crowd[sel] = crowding_distance(np.asarray(costs)[sel])
    # stable lexicographic: rank ascending, crowding descending
    return np.lexsort((-crowd, ranks))
