"""DSE reporting + CLI entry point.

``python -m repro.dse.reports --designs 64 --traces 4`` sweeps a
latin-hypercube batch (or runs the refinement loop with ``--rounds > 1``)
and prints the non-dominated (latency, energy, peak-temp) front as an ASCII
table plus a CSV block, with a design-points/sec figure for the batched
evaluator.
"""
from __future__ import annotations

import argparse
import io
import time
from typing import List, Optional, Sequence

import numpy as np

from ..core.applications import REFERENCE_APPS
from .pareto import pareto_order
from .search import EvalResult, SearchResult, evaluate, pareto_search
from .space import DesignSpace

_COLS = ("design", "area_mm2", "avg_latency_us", "energy_j", "peak_temp_c")


def _front_rows(result: EvalResult) -> List[dict]:
    obj = result.objectives()
    mask = result.front_mask()
    idx = np.flatnonzero(mask)
    order = pareto_order(obj[mask])
    rows = []
    for i in order:
        p = result.points[idx[i]]
        rows.append(dict(design=p.label(), area_mm2=p.area_mm2,
                         avg_latency_us=obj[idx[i], 0],
                         energy_j=obj[idx[i], 1],
                         peak_temp_c=obj[idx[i], 2]))
    return rows


def format_front(result: EvalResult) -> str:
    """ASCII table of the non-dominated front, best-crowding first."""
    rows = _front_rows(result)
    out = io.StringIO()
    out.write(f"Pareto front: {len(rows)} of {result.num_designs} designs\n")
    out.write(f"{'design':>26} {'area':>7} {'latency_us':>11} "
              f"{'energy_j':>10} {'peak_C':>7}\n")
    for r in rows:
        out.write(f"{r['design']:>26} {r['area_mm2']:>7.1f} "
                  f"{r['avg_latency_us']:>11.2f} {r['energy_j']:>10.4f} "
                  f"{r['peak_temp_c']:>7.2f}\n")
    return out.getvalue()


def front_csv(result: EvalResult) -> str:
    rows = _front_rows(result)
    out = io.StringIO()
    out.write(",".join(_COLS) + "\n")
    for r in rows:
        out.write(",".join(f"{r[k]:.6f}" if isinstance(r[k], float)
                           else str(r[k]) for k in _COLS) + "\n")
    return out.getvalue()


def main(argv: Optional[Sequence[str]] = None) -> EvalResult:
    ap = argparse.ArgumentParser(description="Batched SoC design-space sweep")
    ap.add_argument("--designs", type=int, default=64,
                    help="design points per batch (LHS sample)")
    ap.add_argument("--traces", type=int, default=4,
                    help="job traces (seeds) per design")
    ap.add_argument("--jobs", type=int, default=32, help="jobs per trace")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="injection rate (jobs/ms)")
    ap.add_argument("--policy", default="etf", choices=["etf", "met"])
    ap.add_argument("--apps", nargs="+", default=["wifi_tx", "wifi_rx"],
                    choices=sorted(REFERENCE_APPS), help="application mix")
    ap.add_argument("--rounds", type=int, default=1,
                    help=">1 runs the Pareto refinement loop")
    ap.add_argument("--budget-mm2", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--csv", action="store_true", help="also print CSV")
    args = ap.parse_args(argv)

    # scenario construction lives in the facade: one declarative config
    from ..scenario import Scenario, TraceSpec
    base = Scenario(apps=tuple(args.apps), scheduler=args.policy,
                    trace=TraceSpec(rate_jobs_per_ms=args.rate,
                                    num_jobs=args.jobs, seed=args.seed))
    apps = base.applications()
    traces = [base.with_seed(args.seed + s).job_trace()
              for s in range(args.traces)]
    space = DesignSpace()

    t0 = time.perf_counter()
    if args.rounds > 1:
        sr: SearchResult = pareto_search(
            space, apps, traces, policy=args.policy, rounds=args.rounds,
            batch_size=args.designs, seed=args.seed,
            budget_mm2=args.budget_mm2)
        result = sr.archive
        for st in sr.rounds:
            print(f"round {st['round']}: evaluated {st['evaluated']:>4} | "
                  f"archive {st['archive']:>4} | front {st['front']:>3}")
    else:
        points = space.sample_lhs(args.designs, seed=args.seed,
                                  budget_mm2=args.budget_mm2)
        result = evaluate(points, apps, traces, policy=args.policy)
    dt = time.perf_counter() - t0

    print(format_front(result))
    sims = result.num_designs * len(traces)
    print(f"{result.num_designs} designs x {len(traces)} traces "
          f"({sims} simulations) in {dt:.2f}s "
          f"= {result.num_designs / dt:.1f} design-points/sec "
          f"(incl. jit compile)")
    if args.csv:
        print(front_csv(result))
    return result


if __name__ == "__main__":
    main()
