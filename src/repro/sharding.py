"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code annotates parameters and activations with *logical* axis names
("batch", "embed", "mlp", "heads", "kv", "vocab", "expert", "seq", ...).
The launcher installs a rule set mapping logical names -> mesh axes; on CPU
smoke tests no rules are installed and every annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


def _rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_STATE, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


# Default rule sets -----------------------------------------------------------

def rules_single_pod() -> Dict[str, MeshAxes]:
    """16×16 (data, model) single-pod mesh."""
    return {
        "batch": "data",
        "kv_batch": "data",      # KV-cache batch dim (can differ from batch:
                                 # weight-stationary decode replicates batch
                                 # activations but keeps the cache sharded)
        "fsdp": "data",          # weight shard axis for gather-on-use FSDP
        "model": "model",        # TP axis: heads / mlp / vocab / experts
        "expert": "model",       # MoE expert parallelism
        "seq": None,             # sequence usually replicated (flag-controlled)
        "kv_seq": "model",       # decode KV-cache sequence dim (flash-decode)
        "q_seq": "model",        # blocked-attention query rows (context par.)
    }


def rules_multi_pod() -> Dict[str, MeshAxes]:
    """2×16×16 (pod, data, model) mesh: DP and FSDP span pod×data.

    FSDP over both axes halves per-chip parameter/optimizer bytes vs the
    single-pod layout; the cross-pod traffic this adds is the weight
    all-gather + gradient reduce-scatter on the DCN-mapped ``pod`` axis
    (compressible — see optim.compression)."""
    r = rules_single_pod()
    r["batch"] = ("pod", "data")
    r["fsdp"] = ("pod", "data")
    return r


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
    """Install mesh + logical rules for model-code annotations."""
    old_rules, old_mesh = _rules(), _mesh()
    _STATE.rules = rules
    _STATE.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.rules = old_rules
        _STATE.mesh = old_mesh


def logical_to_pspec(axes: Sequence[Optional[str]]) -> P:
    """Map logical axis names to a PartitionSpec under the installed rules."""
    rules = _rules()
    if rules is None:
        return P()
    out = []
    for a in axes:
        m = rules.get(a) if a is not None else None
        out.append(m)
    # drop trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules).

    Inside a partial-manual ``shard_map`` region (e.g. the pipeline's 'pod'
    axis) the constraint must be built against the CONTEXT abstract mesh,
    whose axis types carry the Manual marking."""
    rules = _rules()
    mesh = _mesh()
    if rules is None or mesh is None:
        return x
    m = mesh
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.shape and any(
                t == jax.sharding.AxisType.Manual for t in am.axis_types):
            m = am
    except Exception:       # pragma: no cover — older jax
        pass
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, logical_to_pspec(axes)))


def named_sharding(axes: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = _mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_pspec(axes))


def current_mesh() -> Optional[Mesh]:
    return _mesh()


# Lane meshes — 1-D device meshes for embarrassingly-parallel lane axes ------

LANE_AXIS = "lanes"


def lane_mesh(devices: Optional[Sequence[jax.Device]] = None,
              ) -> Optional[Mesh]:
    """A 1-D mesh over the local devices, axis name :data:`LANE_AXIS`.

    The simulator's sweep grids are embarrassingly parallel along their
    leading design/policy lane axis (every lane is an independent vmapped
    simulation), so a flat 1-D mesh is the whole story — no model/data
    split.  Returns ``None`` on a single device: callers fall back to the
    unsharded path and nothing is ever resharded on 1-device hosts.
    """
    devices = tuple(devices) if devices is not None else tuple(
        jax.local_devices())
    if len(devices) <= 1:
        return None
    return Mesh(np.asarray(devices), (LANE_AXIS,))


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """``NamedSharding`` splitting an array's *leading* axis across the
    lane mesh (remaining axes replicated)."""
    return NamedSharding(mesh, P(LANE_AXIS))


def lane_count(mesh: Optional[Mesh]) -> int:
    """Devices along the lane axis (1 when unsharded)."""
    return 1 if mesh is None else int(mesh.shape[LANE_AXIS])


def mesh_axis(logical: str):
    """(mesh axis name(s), total size) the logical axis maps to, or (None, 1)."""
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return None, 1
    ax = rules.get(logical)
    if ax is None:
        return None, 1
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return axes, size
