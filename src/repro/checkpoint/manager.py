"""Fault-tolerant checkpointing: atomic manifests, async writes, elastic
restore.

Layout:
    <dir>/step_000123/arrays.npz     flattened '/'-keyed leaf arrays
    <dir>/step_000123/meta.json      data-pipeline state, step, extra metadata
    <dir>/MANIFEST.json              {"latest": 123, "steps": [...]}  (atomic)

Guarantees:
* A checkpoint only becomes visible when MANIFEST.json is atomically
  replaced — a crash mid-write (node preemption) leaves the previous
  checkpoint as the restore point.
* ``save(..., blocking=False)`` runs serialization on a writer thread; the
  training loop only pays for the device→host copy.
* ``restore(shardings=...)`` re-shards every leaf onto the CURRENT mesh:
  resuming on a different topology (elastic scale-up/down) is a first-class
  path, not an afterthought.
* ``keep_last`` old checkpoints are garbage-collected after a successful
  manifest bump.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Pytree:
    tree: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Pytree, meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        flat = _flatten(tree)                      # device->host copy here
        meta = dict(meta or {})
        meta["step"] = int(step)
        # npz can't represent ml_dtypes (bf16, fp8): store bit-views + a map
        host, dtypes = {}, {}
        for k, v in flat.items():
            a = np.asarray(v)
            if a.dtype.kind not in "biufc":        # non-native (e.g. bfloat16)
                dtypes[k] = str(a.dtype)
                a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
            host[k] = a
        meta["_dtypes"] = dtypes

        def write():
            step_dir = self.dir / f"step_{step:09d}"
            tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
            try:
                np.savez(tmp / "arrays.npz", **host)
                (tmp / "meta.json").write_text(json.dumps(meta))
                if step_dir.exists():
                    shutil.rmtree(step_dir)
                os.replace(tmp, step_dir)
                self._bump_manifest(step)
                self._gc()
            finally:
                if tmp.exists():
                    shutil.rmtree(tmp, ignore_errors=True)

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _bump_manifest(self, step: int) -> None:
        steps = sorted(set(self.steps() + [step]))
        tmp = self.dir / ".MANIFEST.tmp"
        tmp.write_text(json.dumps({"latest": step, "steps": steps}))
        os.replace(tmp, self.dir / "MANIFEST.json")   # atomic commit point

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
        manifest = {"latest": steps[-1], "steps": steps[-self.keep_last:]}
        tmp = self.dir / ".MANIFEST.tmp"
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, self.dir / "MANIFEST.json")

    # --------------------------------------------------------------- restore
    def steps(self):
        mf = self.dir / "MANIFEST.json"
        if not mf.exists():
            return []
        return list(json.loads(mf.read_text()).get("steps", []))

    def latest_step(self) -> Optional[int]:
        mf = self.dir / "MANIFEST.json"
        if not mf.exists():
            return None
        return json.loads(mf.read_text()).get("latest")

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Pytree] = None):
        """Returns (tree, meta).  ``shardings``: optional pytree of
        NamedShardings (same structure) — leaves are placed onto the current
        mesh (elastic resume on any topology)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        step_dir = self.dir / f"step_{step:09d}"
        with np.load(step_dir / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        meta = json.loads((step_dir / "meta.json").read_text())
        import ml_dtypes
        for k, name in meta.get("_dtypes", {}).items():
            flat[k] = flat[k].view(np.dtype(getattr(ml_dtypes, name)))
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten_shardings(shardings)
            tree = jax.tree.map(lambda x: x, tree)   # deep copy structure
            tree = _place(tree, flat_s, "")
        return tree, meta


def _flatten_shardings(tree: Pytree, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_shardings(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _place(tree: Pytree, flat_s: Dict[str, Any], prefix: str) -> Pytree:
    if isinstance(tree, dict):
        return {k: _place(v, flat_s, f"{prefix}{k}/") for k, v in tree.items()}
    s = flat_s.get(prefix[:-1])
    return jax.device_put(tree, s) if s is not None else jax.device_put(tree)
