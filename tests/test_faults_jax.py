"""Fail-stop fault injection in the JAX kernel (DESIGN.md §14).

Covers the tentpole contract of the fault lanes:

* ref ↔ jax bit-for-bit equality on comm-free traces with fail-stop faults
  (single, multi, simultaneous, accelerator wipeout, DTPM closed loop);
* graceful degradation: accelerator-class tasks fall back to CPU PEs when
  every accelerator dies — the run completes, slower;
* the ``faults`` sweep lane axis equals per-scenario ``run()`` and adds
  ZERO compiles per policy shape (``sweep.compile_count``);
* no-op fault specs (empty / all-``inf``) take the fault-free fast path in
  both ``run`` and ``sweep``;
* telemetry reports zero utilisation on dead PEs past their fail time;
* the :class:`FaultSpec` pytree spec, its bare-tuple deprecation shim, and
  the typed ``ScenarioError`` hierarchy.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (deterministic_trace, get_scheduler, make_soc_table2,
                        poisson_trace, wifi_tx)
from repro.core.dvfs import OndemandGovernor
from repro.core.resources import CommModel
from repro.core.simkernel_jax import build_tables, simulate_jax, \
    simulate_jax_dtpm
from repro.core.simkernel_ref import simulate
from repro.scenario import (BackendCapabilityError, FaultSpec, LaneAxisError,
                            Scenario, ScenarioError, TraceSpec,
                            pe_loss_faults, run, sweep)
from repro.scenario.faults import (fault_plan, fault_scan_steps,
                                   normalize_failures, ref_failures,
                                   stack_fault_plans)
from repro.scenario.sweep import compile_count

SCN = Scenario(apps=("wifi_tx",),
               trace=TraceSpec(rate_jobs_per_ms=25.0, num_jobs=24, seed=3))


def _comm_free_db():
    db = make_soc_table2()
    db.comm = CommModel(startup_us=0.0, bw_bytes_per_us=1e30)
    return db


def _plan(db, failures):
    return fault_plan(normalize_failures(failures), db.num_pes)


def _assert_bitforbit(db, policy, failures, trace):
    """Every ref record matches the jax grid bit for bit; no extra commits."""
    app = wifi_tx()
    ref = simulate(db, [app], trace, get_scheduler(policy),
                   failures=ref_failures(normalize_failures(failures)))
    tables = build_tables(db, [app])
    jx = simulate_jax(tables, policy, trace.arrival_us, trace.app_index,
                      faults=_plan(db, failures))
    fin = np.asarray(jx["finish"])
    start = np.asarray(jx["start"])
    onpe = np.asarray(jx["onpe"])
    for r in ref.records:
        assert fin[r.job_id, r.task_id] == np.float32(r.finish_us)
        assert start[r.job_id, r.task_id] == np.float32(r.start_us)
        assert onpe[r.job_id, r.task_id] == r.pe_id
    assert int(np.asarray(jx["scheduled"]).sum()) == len(ref.records)
    assert float(jx["makespan_us"]) == np.float32(ref.makespan_us)
    np.testing.assert_allclose(float(jx["energy_j"]),
                               ref.energy.total_energy_j, rtol=1e-5)
    return ref, jx


# ------------------------------------------------- kernel-level bit-for-bit

@pytest.mark.parametrize("policy", ["etf", "met"])
def test_single_fault_bitforbit(policy):
    db = _comm_free_db()
    trace = deterministic_trace(25.0, 48, ["wifi_tx"])
    _assert_bitforbit(db, policy, [FaultSpec(0, 500.0)], trace)


def test_fault_at_t0_and_multi_fault_bitforbit():
    db = _comm_free_db()
    trace = deterministic_trace(25.0, 48, ["wifi_tx"])
    _assert_bitforbit(db, "etf", [FaultSpec(1, 0.0)], trace)
    _assert_bitforbit(db, "etf", [FaultSpec(0, 300.0), FaultSpec(1, 800.0)],
                      trace)
    # simultaneous faults apply as one union rollback
    _assert_bitforbit(db, "etf", [FaultSpec(0, 400.0), FaultSpec(2, 400.0)],
                      trace)


def test_multi_fault_poisson_bitforbit():
    db = _comm_free_db()
    trace = poisson_trace(20.0, 64, ["wifi_tx"], seed=3)
    _assert_bitforbit(db, "met", [FaultSpec(0, 300.0), FaultSpec(4, 700.0)],
                      trace)


def test_accelerator_wipeout_degrades_gracefully():
    """All accelerators dead: their tasks fall back to CPU PEs; the run
    completes (bit-for-bit equal to ref) with a strictly worse makespan."""
    db = _comm_free_db()
    accel = [j for j, pe in enumerate(db.pes) if not pe.is_cpu]
    assert accel, "table2 SoC must have accelerator PEs"
    trace = deterministic_trace(25.0, 48, ["wifi_tx"])
    wipe = [FaultSpec(p, 600.0) for p in accel]
    ref, jx = _assert_bitforbit(db, "etf", wipe, trace)
    free = simulate(db, [wifi_tx()], trace, get_scheduler("etf"))
    assert ref.makespan_us > free.makespan_us
    onpe = np.asarray(jx["onpe"])[np.asarray(jx["scheduled"])]
    fin = np.asarray(jx["finish"])[np.asarray(jx["scheduled"])]
    # nothing finishes on a dead accelerator after its fail time
    assert not np.any(np.isin(onpe, accel) & (fin > 600.0))


def test_dtpm_faults_bitforbit():
    db = _comm_free_db()
    app = wifi_tx()
    gov = OndemandGovernor()
    trace = deterministic_trace(25.0, 32, ["wifi_tx"])
    failures = [FaultSpec(0, 300.0), FaultSpec(4, 700.0)]
    ref = simulate(db, [app], trace, get_scheduler("etf"), gov,
                   failures=ref_failures(failures))
    tables = build_tables(db, [app], governor=gov)
    jx = simulate_jax_dtpm(tables, "etf", trace.arrival_us, trace.app_index,
                           gov.policy(), faults=_plan(db, failures))
    fin = np.asarray(jx["finish"])
    for r in ref.records:
        assert fin[r.job_id, r.task_id] == np.float32(r.finish_us)
    assert float(jx["makespan_us"]) == np.float32(ref.makespan_us)
    np.testing.assert_allclose(float(jx["energy_j"]),
                               ref.energy.total_energy_j, rtol=1e-5)


# ------------------------------------------------------------ facade: run()

def test_run_faults_ref_jax_agree():
    scn = SCN.replace(failures=(FaultSpec(0, 500.0),))
    ref = run(scn, backend="ref")
    jx = run(scn, backend="jax")
    assert np.float32(ref.makespan_us) == np.float32(jx.makespan_us)
    np.testing.assert_allclose(jx.energy_j, ref.energy_j, rtol=1e-3)


def test_noop_faults_take_fault_free_fast_path():
    """Empty / all-inf fault specs normalise to the exact fault-free call."""
    free = run(SCN, backend="jax")
    for failures in ((), (FaultSpec(0, float("inf")),)):
        res = run(SCN.replace(failures=failures), backend="jax")
        assert res.makespan_us == free.makespan_us
        assert res.energy_j == free.energy_j
    assert fault_plan((), 14) is None
    assert fault_plan((FaultSpec(3, float("inf")),), 14) is None
    plans, max_f = stack_fault_plans([(), (FaultSpec(0, np.inf),)], 14)
    assert plans is None and max_f == 0


# ----------------------------------------------------------- facade: sweep()

FAULT_LANES = [
    (),
    (FaultSpec(0, 500.0),),
    (FaultSpec(0, 300.0), FaultSpec(1, 800.0)),
]
RATES = [5.0, 20.0]


def test_fault_lane_sweep_matches_per_scenario_run():
    sr = sweep(SCN, axes={"faults": FAULT_LANES, "rate": RATES})
    assert sr.makespan_us.shape == (len(FAULT_LANES), len(RATES))
    for i, fs in enumerate(FAULT_LANES):
        for j, rate in enumerate(RATES):
            r = run(SCN.at_rate(rate).replace(failures=fs), backend="jax")
            assert np.float32(sr.makespan_us[i, j]) == np.float32(r.makespan_us)
            assert np.float32(sr.energy_j[i, j]) == np.float32(r.energy_j)


def test_fault_lane_sweep_adds_zero_compiles():
    axes = {"faults": FAULT_LANES, "rate": RATES}
    sweep(SCN, axes=axes)                       # warm the faulted program
    n0 = compile_count.value
    sweep(SCN, axes=axes)                       # same policy shape: cached
    assert compile_count.value == n0
    # different fault *values* with the same lane count and per-lane fault
    # budget are data, not shape: still ZERO compiles per policy shape
    sweep(SCN, axes={"faults": [(FaultSpec(5, 50.0),),
                                (FaultSpec(3, 2000.0),),
                                (FaultSpec(1, 10.0), FaultSpec(2, 20.0))],
                     "rate": RATES})
    assert compile_count.value == n0


def test_all_noop_fault_axis_reuses_fault_free_program():
    sweep(SCN, axes={"rate": RATES})            # warm the fault-free program
    n0 = compile_count.value
    sr = sweep(SCN, axes={"faults": [(), (FaultSpec(0, float("inf")),)],
                          "rate": RATES})
    assert compile_count.value == n0            # ZERO extra compiles
    assert sr.makespan_us.shape == (2, len(RATES))
    np.testing.assert_array_equal(sr.makespan_us[0], sr.makespan_us[1])


def test_fault_sweep_composes_with_chunk_and_design_axis():
    d0 = SCN.design
    d1 = dataclasses.replace(d0, num_little=d0.num_little + 2)
    axes = {"design": [d0, d1], "faults": FAULT_LANES[:2], "rate": [10.0]}
    base = sweep(SCN, axes=axes)
    chunked = sweep(SCN, axes=axes, chunk=1)
    np.testing.assert_array_equal(base.makespan_us, chunked.makespan_us)
    np.testing.assert_array_equal(base.energy_j, chunked.energy_j)
    r = run(SCN.at_rate(10.0).replace(design=d1,
                                      failures=FAULT_LANES[1]),
            backend="jax")
    assert np.float32(base.makespan_us[1, 1, 0]) == np.float32(r.makespan_us)


def test_fault_sweep_ref_backend_lane_by_lane():
    jx = sweep(SCN, axes={"faults": FAULT_LANES, "rate": [25.0]})
    ref = sweep(SCN, axes={"faults": FAULT_LANES, "rate": [25.0]},
                backend="ref")
    np.testing.assert_allclose(jx.energy_j, ref.energy_j, rtol=1e-3)


# ------------------------------------------------------ telemetry satellite

def test_telemetry_dead_cluster_zero_util_after_fail():
    db = SCN.soc()
    accel = tuple(j for j, pe in enumerate(db.pes) if not pe.is_cpu)
    scn = SCN.replace(failures=tuple(FaultSpec(p, 500.0) for p in accel))
    for backend in ("jax", "ref"):
        tel = run(scn, backend=backend, telemetry=True).telemetry
        t = np.asarray(tel.time_us)
        util = np.asarray(tel.util)
        dead = t > 500.0 + tel.window_us        # windows fully past the fault
        assert dead.any()
        np.testing.assert_array_equal(util[dead][:, -1], 0.0)
        assert util[:, :-1].sum() > 0           # survivors still working


# --------------------------------------------- FaultSpec API + typed errors

def test_faultspec_is_frozen_static_pytree():
    import jax
    f = FaultSpec(pe_id=3, fail_time_us=125.5)
    leaves, _ = jax.tree_util.tree_flatten(f)
    assert leaves == []                          # all-metadata pytree
    assert hash(f) == hash(FaultSpec(3, 125.5))
    with pytest.raises(dataclasses.FrozenInstanceError):
        f.pe_id = 4
    # f32 quantisation keeps ref/jax comparisons aligned
    g = FaultSpec(0, 1e-9)
    assert g.fail_time_us == float(np.float32(1e-9))
    assert FaultSpec(0, np.inf).is_noop and not f.is_noop


def test_faultspec_validation():
    with pytest.raises(ScenarioError, match="kind"):
        FaultSpec(0, 1.0, kind="transient")
    with pytest.raises(ScenarioError, match="pe_id"):
        FaultSpec(-1, 1.0)
    with pytest.raises(ScenarioError, match="NaN"):
        FaultSpec(0, float("nan"))
    with pytest.raises(ScenarioError, match="out of range"):
        fault_plan((FaultSpec(14, 1.0),), 14)


def test_bare_tuple_shim_warns_and_normalises():
    with pytest.warns(DeprecationWarning, match="FaultSpec"):
        scn = SCN.replace(failures=((0, 50.0), (1, 75.0)))
    assert scn.failures == (FaultSpec(0, 50.0), FaultSpec(1, 75.0))
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # FaultSpec form is silent
        scn2 = SCN.replace(failures=(FaultSpec(0, 50.0),))
    assert scn2.failures[0].kind == "fail_stop"
    # the shimmed form still runs (one warning per normalisation call)
    with pytest.warns(DeprecationWarning):
        res = run(SCN.replace(failures=((0, 500.0),)), backend="jax")
    assert res.makespan_us == run(
        SCN.replace(failures=(FaultSpec(0, 500.0),)), backend="jax"
    ).makespan_us


def test_typed_error_hierarchy():
    assert issubclass(ScenarioError, ValueError)
    assert issubclass(BackendCapabilityError, ScenarioError)
    assert issubclass(LaneAxisError, ScenarioError)
    with pytest.raises(ScenarioError, match="unknown backend"):
        run(SCN, backend="gem5")
    with pytest.raises(ScenarioError, match="unknown backend"):
        sweep(SCN, axes={"rate": [5.0]}, backend="gem5")
    with pytest.raises(LaneAxisError, match="unknown sweep axis"):
        sweep(SCN, axes={"voltage": [1.0]})
    with pytest.raises(BackendCapabilityError, match="chunk/shard"):
        sweep(SCN, axes={"rate": [5.0]}, backend="ref", chunk=2)
    with pytest.raises(BackendCapabilityError, match="table"):
        sweep(SCN, axes={"faults": FAULT_LANES[:2],
                         "scheduler": ["etf", "table"]})
    with pytest.raises(BackendCapabilityError, match="telemetry"):
        sweep(SCN.replace(governor="ondemand"),
              axes={"faults": FAULT_LANES[:2]}, telemetry=True)


def test_pe_loss_faults_enumerates_subsets():
    lanes = pe_loss_faults(range(4), fail_time_us=10.0, k=2)
    assert len(lanes) == 6                      # C(4, 2)
    assert all(len(fs) == 2 for fs in lanes)
    assert all(f.fail_time_us == 10.0 for fs in lanes for f in fs)


def test_fault_scan_steps_bound():
    assert fault_scan_steps(10, 6, 0) == 60
    assert fault_scan_steps(10, 6, 2) == 60 * 3 + 2
