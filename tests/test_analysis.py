"""repro.analysis — the lint suite linted by its own fixtures.

Every rule has at least one fixture file that must trip it (with exact
codes and line numbers) and one that must stay clean; the CC001 gate is
exercised against synthetic bench artifacts, including a deliberate
contract violation.  A final dogfood test pins the repo itself to
``--strict`` clean, so CI cannot drift from the lint contract.
"""
import ast
import json
import shutil
import subprocess
import types
from pathlib import Path

import pytest

from repro.analysis import (check_compile_gate, load_config, run_analysis)
from repro.analysis.config import AnalysisConfig
from repro.analysis.engine import changed_files
from repro.analysis.findings import scan_waivers
from repro.analysis.__main__ import main as cli_main

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO = Path(__file__).parent.parent


def lint(*files, strict=False, select=None):
    cfg = AnalysisConfig(root=FIXTURES, paths=tuple(files))
    return run_analysis(cfg, select=select, strict=strict)


def lines(findings, code=None):
    return sorted(f.line for f in findings
                  if code is None or f.code == code)


# ---------------------------------------------------------------- JX001

def test_jx001_bad_exact_sites():
    rep = lint("jx001_bad.py", select=["JX001"])
    assert lines(rep.active, "JX001") == [8, 13, 18, 24]


def test_jx001_good_is_clean():
    rep = lint("jx001_good.py", select=["JX001"])
    assert rep.active == []


# ---------------------------------------------------------------- JX002

def test_jx002_bad_flags_and_waives():
    rep = lint("jx002_bad.py", select=["JX002"])
    assert lines(rep.active, "JX002") == [8]
    waived = [f for f in rep.findings if f.waived]
    assert lines(waived, "JX002") == [13]
    assert waived[0].waiver_reason.startswith("fixture:")


def test_jx002_good_side_of_boundary():
    # jx001_good has host numpy on constants + host driver code: clean
    rep = lint("jx001_good.py", select=["JX002"])
    assert rep.active == []


# ---------------------------------------------------------------- JX003

def test_jx003_bad_exact_sites():
    rep = lint("jx003_bad.py", select=["JX003"])
    assert lines(rep.active, "JX003") == [12, 18, 24, 29, 36]


def test_jx003_good_host_effects_and_waiver():
    rep = lint("jx003_good.py", select=["JX003"])
    assert rep.active == []
    assert lines([f for f in rep.findings if f.waived], "JX003") == [18]


# ---------------------------------------------------------------- PT001

def test_pt001_bad_exact_sites():
    rep = lint("pt001_bad.py", select=["PT001"])
    got = lines(rep.active, "PT001")
    assert got == [13, 23, 33]
    msgs = {f.line: f.message for f in rep.active}
    assert "frozen" in msgs[13]
    assert "missing" in msgs[23]
    assert "meta" in msgs[33]


def test_pt001_good_including_loop_registration():
    rep = lint("pt001_good.py", select=["PT001"])
    assert rep.active == []


# ---------------------------------------------------------------- UN001

def test_un001_bad_fields_and_payload_keys():
    rep = lint("un001_bad.py", select=["UN001"])
    assert lines(rep.active, "UN001") == [9, 11, 15]


def test_un001_good_suffixes_and_allowlist():
    rep = lint("un001_good.py", select=["UN001"])
    assert rep.active == []


# ---------------------------------------------------------------- SC001

def test_sc001_bad_exact_sites():
    rep = lint("sc001_bad.py", select=["SC001"])
    assert lines(rep.active, "SC001") == [7, 16, 25, 34, 43, 54, 65]
    msgs = {f.line: f.message for f in rep.active}
    assert "(carry, ys) pair" in msgs[7]
    assert "arity diverges" in msgs[16]
    assert "reordered" in msgs[25]
    assert "true division" in msgs[34]
    assert "jax.numpy.mean" in msgs[43]
    assert "astype" in msgs[54]
    assert "return paths" in msgs[65]


def test_sc001_good_is_clean():
    # dict carries, floor division, float init, symmetric astype,
    # partial-bound bodies, opaque carry returns: all stable
    rep = lint("sc001_good.py", select=["SC001"])
    assert rep.active == []


# ---------------------------------------------------------------- DN001

def test_dn001_bad_exact_sites():
    rep = lint("dn001_bad.py", select=["DN001"])
    assert lines(rep.active, "DN001") == [14, 20, 32, 44, 52]
    for f in rep.active:
        assert "donated" in f.message and "read again" in f.message


def test_dn001_good_is_clean():
    # fresh buffers per call, rebinds, reads before the call, non-donated
    # keywords, and args of a multi-line donating call never flag
    rep = lint("dn001_good.py", select=["DN001"])
    assert rep.active == []


# ---------------------------------------------------------------- SH001

def test_sh001_bad_exact_sites():
    rep = lint("sh001_bad.py", select=["SH001"])
    assert lines(rep.active, "SH001") == [6, 10, 15, 21]
    msgs = {f.line: f.message for f in rep.active}
    assert "leading axis" in msgs[6]
    assert "device_put" in msgs[15] or "device placement" in msgs[15]
    assert "mesh" in msgs[21]


def test_sh001_good_is_clean():
    rep = lint("sh001_good.py", select=["SH001"])
    assert rep.active == []


# ---------------------------------------------------------------- severity

def test_severity_defaults_warn_gates_only_strict():
    rep = lint("sh001_bad.py", select=["SH001"])
    assert rep.active and all(f.severity == "warn" for f in rep.active)
    assert rep.ok                          # warns pass a default run
    rep = lint("sh001_bad.py", select=["SH001"], strict=True)
    assert not rep.ok                      # --strict promotes warns


def test_severity_overrides_change_gating():
    cfg = AnalysisConfig(root=FIXTURES, paths=("sh001_bad.py",),
                         severity=(("SH001", "error"),))
    assert not run_analysis(cfg, select=["SH001"]).ok
    cfg = AnalysisConfig(root=FIXTURES, paths=("sc001_bad.py",),
                         severity=(("SC001", "info"),))
    rep = run_analysis(cfg, select=["SC001"], strict=True)
    assert rep.active and rep.ok           # info prints, never gates


def test_severity_config_parsing():
    from repro.analysis.config import _parse_severity
    assert _parse_severity(["SH001=error"]) == (("SH001", "error"),)
    assert _parse_severity({"SC001": "info"}) == (("SC001", "info"),)
    with pytest.raises(ValueError):
        _parse_severity(["ZZ999=warn"])
    with pytest.raises(ValueError):
        _parse_severity(["SH001=loud"])


def test_finding_render_uses_severity_word():
    rep = lint("sh001_bad.py", select=["SH001"])
    assert "SH001 warning:" in rep.active[0].render()
    rep = lint("jx001_bad.py", select=["JX001"])
    assert "JX001 error:" in rep.active[0].render()


def test_report_payload_counts_per_severity():
    from repro.analysis.findings import report_payload
    rep = lint("sh001_bad.py", select=["SH001"])
    payload = report_payload(rep.findings)
    assert payload["summary"]["per_severity"] == {"warn": 4}


# ---------------------------------------------------------------- waivers

def test_waiver_scanning_forms():
    src = ("x = 1  # lint: waive JX001 -- same line\n"
           "# lint: waive UN001,PT001 -- next line\n"
           "y = 2\n")
    w = scan_waivers(src)
    assert w[1].codes == {"JX001"}
    assert w[2].codes == w[3].codes == {"UN001", "PT001"}
    assert w[3].reason == "next line"


def test_waiver_multi_code_trailing():
    src = "y = f()  # lint: waive JX003,SC001 -- counts compiles, stable\n"
    w = scan_waivers(src)
    assert w[1].codes == {"JX003", "SC001"}


def test_waiver_standalone_above_decorated_def():
    src = ("import dataclasses\n"
           "# lint: waive PT001 -- fixture: covers the class line too\n"
           "@dataclasses.dataclass\n"
           "class C:\n"
           "    x: int = 0\n")
    w = scan_waivers(src, ast.parse(src))
    assert 2 in w and 3 in w               # comment + first decorator line
    assert 4 in w and w[4].codes == {"PT001"}   # the class line itself
    # without the tree only the next-line form resolves
    w_plain = scan_waivers(src)
    assert 3 in w_plain and 4 not in w_plain


def test_waiver_on_continuation_line():
    src = ("x = (1 +\n"
           "     2)  # lint: waive UN001 -- fixture: continuation\n")
    w = scan_waivers(src, ast.parse(src))
    assert 2 in w
    assert 1 in w and w[1].codes == {"UN001"}   # the statement's lineno
    w_plain = scan_waivers(src)
    assert 1 not in w_plain


def test_wv001_only_in_strict():
    rep = lint("wv001_bad.py", select=["JX002"])
    assert rep.active == []                      # waiver applies
    rep = lint("wv001_bad.py", select=["JX002"], strict=True)
    assert [f.code for f in rep.active] == ["WV001"]


# ---------------------------------------------------------------- CC001

def _bench_payload(bench, counters):
    return {"schema": "repro.obs/bench/v1",
            "manifest": {"bench": bench,
                         "metrics": {"counters": counters}},
            "rows": []}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return p


def test_cc001_within_contract(tmp_path):
    contracts = REPO / "src" / "repro" / "analysis" / "contracts.json"
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"kernel.jax.simulate.compile_count": 1,
                    "scenario.sweep.compile_count": 1}))
    assert check_compile_gate(contracts, [art]) == []


def test_cc001_deliberate_violation_fails_gate(tmp_path):
    # the checked-in contract allows 1 sweep compile for bench speedup; a
    # regressed jit cache key would recompile per call — the gate must trip
    contracts = REPO / "src" / "repro" / "analysis" / "contracts.json"
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 64}))
    findings = check_compile_gate(contracts, [art])
    assert len(findings) == 1
    assert findings[0].code == "CC001"
    assert "scenario.sweep.compile_count" in findings[0].message


def test_cc001_patched_contract_tightens(tmp_path):
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {"scenario.sweep.compile_count": 0}}})
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 1}))
    findings = check_compile_gate(patched, [art])
    assert [f.code for f in findings] == ["CC001"]


def test_cc001_unknown_bench_and_stray_counter(tmp_path):
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {}}})
    unknown = _write(tmp_path, "BENCH_new.json",
                     _bench_payload("brand_new", {}))
    stray = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 2}))
    msgs = [f.message for f in check_compile_gate(patched, [unknown, stray])]
    assert any("no compile-count contract" in m for m in msgs)
    assert any("not in the contract" in m for m in msgs)


def test_cc001_pytest_plugin_flips_exit_status(tmp_path, monkeypatch):
    from repro.analysis import pytest_plugin
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {"scenario.sweep.compile_count": 0}}})
    _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 3}))
    monkeypatch.chdir(tmp_path)

    class _Config:
        def __init__(self):
            self.pluginmanager = types.SimpleNamespace(
                get_plugin=lambda name: None)

        def getoption(self, name):
            return {"--compile-contracts": str(patched),
                    "--compile-bench": "BENCH_*.json"}[name]

    session = types.SimpleNamespace(config=_Config(), exitstatus=0)
    pytest_plugin.pytest_sessionfinish(session, 0)
    assert session.exitstatus == 1


# ---------------------------------------------------------------- CLI

def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in ("JX001", "JX002", "JX003", "PT001", "UN001",
                 "SC001", "DN001", "SH001", "CC001", "WV001"):
        assert code in out
    assert "[warn" in out                  # SH001's default severity shows


def test_cli_exit_codes_and_report(tmp_path, capsys):
    report = tmp_path / "findings.json"
    rc = cli_main(["--root", str(FIXTURES), "--select", "JX001",
                   "--report", str(report), "jx001_bad.py"])
    assert rc == 1
    payload = json.loads(report.read_text())
    assert payload["schema"] == "repro.analysis/report/v1"
    assert payload["summary"]["per_code"]["JX001"] == 4
    rc = cli_main(["--root", str(FIXTURES), "--select", "JX001",
                   "jx001_good.py"])
    assert rc == 0


def test_cli_unknown_rule_is_usage_error(capsys):
    assert cli_main(["--select", "ZZ999"]) == 2


def test_cli_compile_gate(tmp_path, capsys):
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 99}))
    rc = cli_main(["--compile-gate", str(art)])
    assert rc == 1
    rc = cli_main(["--compile-gate", str(_write(
        tmp_path, "ok.json", _bench_payload(
            "speedup", {"scenario.sweep.compile_count": 1})))])
    assert rc == 0


def test_changed_files_runs(tmp_path):
    # no git in tmp_path: must degrade to an empty list, not raise
    assert changed_files(tmp_path) == []
    assert isinstance(changed_files(REPO), list)


# ---------------------------------------------------------------- --fix

def _seed_fix_tree(tmp_path):
    shutil.copy(FIXTURES / "fix_un001.py", tmp_path / "fix_un001.py")
    (tmp_path / "pyproject.toml").write_text(
        '[tool.repro.analysis]\npaths = ["fix_un001.py"]\n')
    return tmp_path


def _exec_summarize(path):
    ns = {}
    exec(compile(path.read_text(), str(path), "exec"), ns)
    return ns["summarize"](2.0)


def test_fix_applies_un001_renames(tmp_path, capsys):
    root = _seed_fix_tree(tmp_path)
    assert cli_main(["--root", str(root), "--select", "UN001"]) == 1
    assert cli_main(["--root", str(root), "--fix",
                     "--select", "UN001"]) == 0
    src = (root / "fix_un001.py").read_text()
    assert "energy_j: float" in src
    assert "power_w: float" in src
    assert "latency_us: float" in src
    assert '"latency_us"' in src               # dict keys follow the field
    assert "EnergyReport(energy_j=1.0" in src  # constructor call site
    assert "rep.energy_j" in src               # inferred attribute read
    assert "num_jobs: int" in src              # allow-listed name untouched


def test_fix_is_idempotent_and_behavior_preserving(tmp_path, capsys):
    root = _seed_fix_tree(tmp_path)
    before = _exec_summarize(root / "fix_un001.py")
    assert cli_main(["--root", str(root), "--fix",
                     "--select", "UN001"]) == 0
    first = (root / "fix_un001.py").read_text()
    capsys.readouterr()
    assert cli_main(["--root", str(root), "--fix",
                     "--select", "UN001"]) == 0
    assert (root / "fix_un001.py").read_text() == first
    assert "applied 0 edit(s)" in capsys.readouterr().out
    assert _exec_summarize(root / "fix_un001.py") == before


def test_fix_skips_waived_sites(tmp_path):
    root = _seed_fix_tree(tmp_path)
    src = (root / "fix_un001.py").read_text().replace(
        "    energy: float",
        "    # lint: waive UN001 -- fixture: stays dimensionless\n"
        "    energy: float")
    (root / "fix_un001.py").write_text(src)
    from repro.analysis.fix import apply_fixes, plan_fixes
    from repro.analysis.project import ProjectIndex
    cfg = load_config(root)
    result = apply_fixes(root, plan_fixes(
        ProjectIndex.build(root, cfg.paths), cfg))
    fixed = (root / "fix_un001.py").read_text()
    assert "    energy: float" in fixed        # waived field kept
    assert "power_w: float" in fixed           # the others still fixed
    assert any("waived" in note for note in result.skipped)


# ---------------------------------------------------------------- SARIF

def test_sarif_shape_and_suppressions(tmp_path):
    sarif = tmp_path / "findings.sarif"
    rc = cli_main(["--root", str(FIXTURES), "--select", "JX002",
                   "--sarif", str(sarif), "jx002_bad.py"])
    assert rc == 1
    log = json.loads(sarif.read_text())
    assert log["version"] == "2.1.0"
    assert "sarif-schema-2.1.0" in log["$schema"]
    run = log["runs"][0]
    assert run["tool"]["driver"]["name"] == "repro.analysis"
    rule_ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert {"JX001", "UN001", "SC001", "DN001", "SH001"} <= set(rule_ids)
    results = run["results"]
    assert len(results) == 2                   # one active + one waived
    active = [r for r in results if "suppressions" not in r]
    waived = [r for r in results if "suppressions" in r]
    assert len(active) == 1 and len(waived) == 1
    assert active[0]["ruleId"] == "JX002"
    assert active[0]["level"] == "error"
    loc = active[0]["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "jx002_bad.py"
    assert loc["region"]["startLine"] == 8
    assert loc["region"]["startColumn"] >= 1
    sup = waived[0]["suppressions"][0]
    assert sup["kind"] == "inSource"
    assert sup["justification"].startswith("fixture:")


def test_sarif_levels_follow_severity():
    from repro.analysis.sarif import sarif_payload
    rep = lint("sh001_bad.py", select=["SH001"])
    log = sarif_payload(rep.findings)
    levels = {r["level"] for r in log["runs"][0]["results"]}
    assert levels == {"warning"}               # SH001 defaults to warn


def test_cli_format_sarif_stdout(capsys):
    rc = cli_main(["--root", str(FIXTURES), "--select", "JX001",
                   "--format", "sarif", "jx001_bad.py"])
    assert rc == 1
    log = json.loads(capsys.readouterr().out)
    assert log["version"] == "2.1.0"
    assert len(log["runs"][0]["results"]) == 4


# ---------------------------------------------------------- changed-files

def _git(tmp, *args):
    subprocess.run(["git", "-c", "user.email=t@t", "-c", "user.name=t",
                    *args], cwd=tmp, check=True, capture_output=True)


def test_changed_files_resolves_renames(tmp_path):
    _git(tmp_path, "init", "-q")
    (tmp_path / "mod_a.py").write_text("VALUE = 1\n" * 20)
    _git(tmp_path, "add", ".")
    _git(tmp_path, "commit", "-qm", "seed")
    base = subprocess.run(["git", "rev-parse", "HEAD"], cwd=tmp_path,
                          capture_output=True, text=True).stdout.strip()
    _git(tmp_path, "mv", "mod_a.py", "mod_b.py")
    _git(tmp_path, "commit", "-qm", "rename")
    # a pure rename (R100) is content-identical to the base: nothing to lint
    assert changed_files(tmp_path, base) == []
    (tmp_path / "mod_b.py").write_text("VALUE = 1\n" * 20 + "EXTRA = 2\n")
    _git(tmp_path, "commit", "-aqm", "edit")
    # rename + edit lints the new path only — never the vanished old one
    assert changed_files(tmp_path, base) == ["mod_b.py"]


def test_cc001_message_names_bench_counter_and_delta(tmp_path):
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {"scenario.sweep.compile_count": 1}}})
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 64}))
    msg = check_compile_gate(patched, [art])[0].message
    assert "benchmark `speedup`" in msg
    assert "`scenario.sweep.compile_count`" in msg
    assert "+63 over budget" in msg


# ---------------------------------------------------------------- dogfood

def test_repo_is_strict_clean():
    cfg = load_config(REPO)
    rep = run_analysis(cfg, ignore=["CC001"], strict=True)
    assert rep.active == [], "\n".join(f.render() for f in rep.active)
    # the deliberate compile-counter waivers stay visible, not silenced
    waived = [f for f in rep.findings if f.waived and f.code == "JX003"]
    assert len(waived) >= 6
    assert all(f.waiver_reason for f in waived)


def test_repo_reachability_covers_kernels():
    from repro.analysis.project import ProjectIndex
    from repro.analysis.reachability import compute_reachable
    cfg = load_config(REPO)
    idx = ProjectIndex.build(cfg.root, cfg.paths)
    reach = compute_reachable(idx)
    names = {u.name for u in reach}
    # jit roots and their transitive callees, across modules
    for expected in ("_simulate", "_simulate_dtpm", "_sweep_grid",
                     "_epoch_scan", "exact_step_jax"):
        assert expected in names, sorted(names)
    assert {"policy", "num_jobs"} <= set(reach.static_param_names)
