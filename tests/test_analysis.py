"""repro.analysis — the lint suite linted by its own fixtures.

Every rule has at least one fixture file that must trip it (with exact
codes and line numbers) and one that must stay clean; the CC001 gate is
exercised against synthetic bench artifacts, including a deliberate
contract violation.  A final dogfood test pins the repo itself to
``--strict`` clean, so CI cannot drift from the lint contract.
"""
import json
import types
from pathlib import Path

import pytest

from repro.analysis import (check_compile_gate, load_config, run_analysis)
from repro.analysis.config import AnalysisConfig
from repro.analysis.engine import changed_files
from repro.analysis.findings import scan_waivers
from repro.analysis.__main__ import main as cli_main

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO = Path(__file__).parent.parent


def lint(*files, strict=False, select=None):
    cfg = AnalysisConfig(root=FIXTURES, paths=tuple(files))
    return run_analysis(cfg, select=select, strict=strict)


def lines(findings, code=None):
    return sorted(f.line for f in findings
                  if code is None or f.code == code)


# ---------------------------------------------------------------- JX001

def test_jx001_bad_exact_sites():
    rep = lint("jx001_bad.py", select=["JX001"])
    assert lines(rep.active, "JX001") == [8, 13, 18, 24]


def test_jx001_good_is_clean():
    rep = lint("jx001_good.py", select=["JX001"])
    assert rep.active == []


# ---------------------------------------------------------------- JX002

def test_jx002_bad_flags_and_waives():
    rep = lint("jx002_bad.py", select=["JX002"])
    assert lines(rep.active, "JX002") == [8]
    waived = [f for f in rep.findings if f.waived]
    assert lines(waived, "JX002") == [13]
    assert waived[0].waiver_reason.startswith("fixture:")


def test_jx002_good_side_of_boundary():
    # jx001_good has host numpy on constants + host driver code: clean
    rep = lint("jx001_good.py", select=["JX002"])
    assert rep.active == []


# ---------------------------------------------------------------- JX003

def test_jx003_bad_exact_sites():
    rep = lint("jx003_bad.py", select=["JX003"])
    assert lines(rep.active, "JX003") == [12, 18, 24, 29, 36]


def test_jx003_good_host_effects_and_waiver():
    rep = lint("jx003_good.py", select=["JX003"])
    assert rep.active == []
    assert lines([f for f in rep.findings if f.waived], "JX003") == [18]


# ---------------------------------------------------------------- PT001

def test_pt001_bad_exact_sites():
    rep = lint("pt001_bad.py", select=["PT001"])
    got = lines(rep.active, "PT001")
    assert got == [13, 23, 33]
    msgs = {f.line: f.message for f in rep.active}
    assert "frozen" in msgs[13]
    assert "missing" in msgs[23]
    assert "meta" in msgs[33]


def test_pt001_good_including_loop_registration():
    rep = lint("pt001_good.py", select=["PT001"])
    assert rep.active == []


# ---------------------------------------------------------------- UN001

def test_un001_bad_fields_and_payload_keys():
    rep = lint("un001_bad.py", select=["UN001"])
    assert lines(rep.active, "UN001") == [9, 11, 15]


def test_un001_good_suffixes_and_allowlist():
    rep = lint("un001_good.py", select=["UN001"])
    assert rep.active == []


# ---------------------------------------------------------------- waivers

def test_waiver_scanning_forms():
    src = ("x = 1  # lint: waive JX001 -- same line\n"
           "# lint: waive UN001,PT001 -- next line\n"
           "y = 2\n")
    w = scan_waivers(src)
    assert w[1].codes == {"JX001"}
    assert w[2].codes == w[3].codes == {"UN001", "PT001"}
    assert w[3].reason == "next line"


def test_wv001_only_in_strict():
    rep = lint("wv001_bad.py", select=["JX002"])
    assert rep.active == []                      # waiver applies
    rep = lint("wv001_bad.py", select=["JX002"], strict=True)
    assert [f.code for f in rep.active] == ["WV001"]


# ---------------------------------------------------------------- CC001

def _bench_payload(bench, counters):
    return {"schema": "repro.obs/bench/v1",
            "manifest": {"bench": bench,
                         "metrics": {"counters": counters}},
            "rows": []}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return p


def test_cc001_within_contract(tmp_path):
    contracts = REPO / "src" / "repro" / "analysis" / "contracts.json"
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"kernel.jax.simulate.compile_count": 1,
                    "scenario.sweep.compile_count": 1}))
    assert check_compile_gate(contracts, [art]) == []


def test_cc001_deliberate_violation_fails_gate(tmp_path):
    # the checked-in contract allows 1 sweep compile for bench speedup; a
    # regressed jit cache key would recompile per call — the gate must trip
    contracts = REPO / "src" / "repro" / "analysis" / "contracts.json"
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 64}))
    findings = check_compile_gate(contracts, [art])
    assert len(findings) == 1
    assert findings[0].code == "CC001"
    assert "scenario.sweep.compile_count" in findings[0].message


def test_cc001_patched_contract_tightens(tmp_path):
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {"scenario.sweep.compile_count": 0}}})
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 1}))
    findings = check_compile_gate(patched, [art])
    assert [f.code for f in findings] == ["CC001"]


def test_cc001_unknown_bench_and_stray_counter(tmp_path):
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {}}})
    unknown = _write(tmp_path, "BENCH_new.json",
                     _bench_payload("brand_new", {}))
    stray = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 2}))
    msgs = [f.message for f in check_compile_gate(patched, [unknown, stray])]
    assert any("no compile-count contract" in m for m in msgs)
    assert any("not in the contract" in m for m in msgs)


def test_cc001_pytest_plugin_flips_exit_status(tmp_path, monkeypatch):
    from repro.analysis import pytest_plugin
    patched = _write(tmp_path, "contracts.json", {
        "schema": "repro.analysis/contracts/v1",
        "contracts": {"speedup": {"scenario.sweep.compile_count": 0}}})
    _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 3}))
    monkeypatch.chdir(tmp_path)

    class _Config:
        def __init__(self):
            self.pluginmanager = types.SimpleNamespace(
                get_plugin=lambda name: None)

        def getoption(self, name):
            return {"--compile-contracts": str(patched),
                    "--compile-bench": "BENCH_*.json"}[name]

    session = types.SimpleNamespace(config=_Config(), exitstatus=0)
    pytest_plugin.pytest_sessionfinish(session, 0)
    assert session.exitstatus == 1


# ---------------------------------------------------------------- CLI

def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in ("JX001", "JX002", "JX003", "PT001", "UN001", "CC001"):
        assert code in out


def test_cli_exit_codes_and_report(tmp_path, capsys):
    report = tmp_path / "findings.json"
    rc = cli_main(["--root", str(FIXTURES), "--select", "JX001",
                   "--report", str(report), "jx001_bad.py"])
    assert rc == 1
    payload = json.loads(report.read_text())
    assert payload["schema"] == "repro.analysis/report/v1"
    assert payload["summary"]["per_code"]["JX001"] == 4
    rc = cli_main(["--root", str(FIXTURES), "--select", "JX001",
                   "jx001_good.py"])
    assert rc == 0


def test_cli_unknown_rule_is_usage_error(capsys):
    assert cli_main(["--select", "ZZ999"]) == 2


def test_cli_compile_gate(tmp_path, capsys):
    art = _write(tmp_path, "BENCH_speedup.json", _bench_payload(
        "speedup", {"scenario.sweep.compile_count": 99}))
    rc = cli_main(["--compile-gate", str(art)])
    assert rc == 1
    rc = cli_main(["--compile-gate", str(_write(
        tmp_path, "ok.json", _bench_payload(
            "speedup", {"scenario.sweep.compile_count": 1})))])
    assert rc == 0


def test_changed_files_runs(tmp_path):
    # no git in tmp_path: must degrade to an empty list, not raise
    assert changed_files(tmp_path) == []
    assert isinstance(changed_files(REPO), list)


# ---------------------------------------------------------------- dogfood

def test_repo_is_strict_clean():
    cfg = load_config(REPO)
    rep = run_analysis(cfg, ignore=["CC001"], strict=True)
    assert rep.active == [], "\n".join(f.render() for f in rep.active)
    # the deliberate compile-counter waivers stay visible, not silenced
    waived = [f for f in rep.findings if f.waived and f.code == "JX003"]
    assert len(waived) >= 6
    assert all(f.waiver_reason for f in waived)


def test_repo_reachability_covers_kernels():
    from repro.analysis.project import ProjectIndex
    from repro.analysis.reachability import compute_reachable
    cfg = load_config(REPO)
    idx = ProjectIndex.build(cfg.root, cfg.paths)
    reach = compute_reachable(idx)
    names = {u.name for u in reach}
    # jit roots and their transitive callees, across modules
    for expected in ("_simulate", "_simulate_dtpm", "_sweep_grid",
                     "_epoch_scan", "exact_step_jax"):
        assert expected in names, sorted(names)
    assert {"policy", "num_jobs"} <= set(reach.static_param_names)
