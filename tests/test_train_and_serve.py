"""End-to-end driver tests: training loop (fault tolerance, determinism),
serving engine (continuous batching correctness)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.launch.train import train, train_with_retries
from repro.models import build_model
from repro.serving import Request, ServeEngine


def test_train_loss_decreases(tmp_path):
    params, losses, _ = train(arch="mamba2-130m", preset="tiny", steps=30,
                              batch=8, seq=64, lr=3e-3)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_preemption_resume_bit_exact(tmp_path):
    """5 steps + preemption + resume == 10 uninterrupted steps."""
    kw = dict(arch="mamba2-130m", preset="tiny", steps=10, batch=4, seq=32,
              lr=1e-3, ckpt_every=5, seed=1)
    p_straight, _, _ = train(ckpt_dir=str(tmp_path / "a"), **kw)
    p_resumed, _, _ = train_with_retries(
        ckpt_dir=str(tmp_path / "b"), fail_at=7, **kw)
    flat1 = jax.tree.leaves(p_straight)
    flat2 = jax.tree.leaves(p_resumed)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_train_with_compression_still_converges():
    _, losses, _ = train(arch="mamba2-130m", preset="tiny", steps=30,
                         batch=8, seq=64, lr=3e-3, compress_grads=True)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_train_with_accumulation_matches_loss_scale():
    _, losses, _ = train(arch="mamba2-130m", preset="tiny", steps=10,
                         batch=8, seq=32, lr=1e-3, accum=4)
    assert np.isfinite(losses).all()


def test_straggler_watchdog_flags_slow_step():
    from repro.launch.train import StragglerWatchdog
    wd = StragglerWatchdog(factor=3.0, warmup=3)
    flagged = [wd.observe(i, 0.1) for i in range(8)]
    assert not any(flagged)
    assert wd.observe(9, 1.0)          # 10× median -> straggler
    assert wd.events and wd.events[0]["step"] == 9


# ---------------------------------------------------------------- serving

@pytest.fixture(scope="module")
def serve_setup():
    cfg = reduced(get_config("gemma2-2b")).replace(window_size=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new):
    """Teacher-forced greedy continuation via full forwards (oracle)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits = model.forward_logits(params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_teacher_forced_greedy(serve_setup):
    cfg, model, params = serve_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9)]
    eng = ServeEngine(model, params, num_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r in reqs:
        want = _greedy_reference(model, params, list(r.prompt), 6)
        assert r.output == want, (r.rid, r.output, want)


def test_engine_slot_recycling_more_requests_than_slots(serve_setup):
    cfg, model, params = serve_setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(model, params, num_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=3) for i in range(5)]
    eng.run(reqs)
    assert all(r.finish_s is not None and len(r.output) == 3 for r in reqs)


def test_engine_with_ds3_arrival_process(serve_setup):
    """The paper's job generator drives serving arrivals."""
    from repro.core import poisson_trace
    cfg, model, params = serve_setup
    trace = poisson_trace(rate_jobs_per_ms=0.2, num_jobs=4,
                          app_names=["llm"], seed=0)
    rng = np.random.default_rng(2)
    eng = ServeEngine(model, params, num_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=2,
                    arrival_s=float(t) * 1e-6)      # us -> s (sped up)
            for i, t in enumerate(trace.arrival_us)]
    eng.run(reqs)
    assert all(r.latency_s is not None and r.latency_s >= 0 for r in reqs)
