"""repro.scenario — unified facade tests.

Covers the backend-equivalence contract (run() is a bit-for-bit delegate to
the legacy entry points), the sweep contract (cross-product axes == per-point
run(), ONE compiled program per scheduler), the deprecation shims, and the
removal of the expired *_mj → *_j energy aliases.  Closed-loop DTPM (dynamic
governors on the jax backend) is covered in tests/test_dtpm.py.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.core as core
import repro.dse as dse
from repro.core import simkernel_jax as skj
from repro.core import simkernel_ref as skr
from repro.core.dvfs import OndemandGovernor, UserspaceGovernor
from repro.core.resources import CPU_BIG, CPU_LITTLE, make_soc_table2
from repro.core.schedulers import get_scheduler
from repro.dse import DesignPoint, build_design_batch, stack_traces
from repro.scenario import (FaultSpec, Result, Scenario, ThermalSpec,
                            TraceSpec, run, sweep)
from repro.scenario.sweep import compile_count

SCN = Scenario(apps=("wifi_tx",),
               trace=TraceSpec(rate_jobs_per_ms=25.0, num_jobs=24, seed=3))
MIX = Scenario(apps=("wifi_tx", "wifi_rx"),
               trace=TraceSpec(rate_jobs_per_ms=20.0, num_jobs=16, seed=1))


# --------------------------------------------------------- scenario config

def test_scenario_is_static_hashable_pytree():
    leaves, _ = jax.tree_util.tree_flatten(SCN)
    assert leaves == []                     # all fields are static metadata
    assert hash(SCN) == hash(SCN.replace())
    assert SCN.replace(**{"trace.seed": 9}).trace.seed == 9
    assert SCN.at_rate(60.0).trace.rate_jobs_per_ms == 60.0
    assert {SCN: "cache-key"}[SCN] == "cache-key"


def test_default_design_is_the_table2_soc():
    db, ref = SCN.soc(), make_soc_table2()
    assert [(p.pe_type, p.cluster, p.name) for p in db.pes] \
        == [(p.pe_type, p.cluster, p.name) for p in ref.pes]


def test_governor_materialisation():
    assert SCN.make_governor().name == "performance"
    gov = SCN.replace(governor="design").make_governor()
    assert isinstance(gov, UserspaceGovernor)
    assert gov.initial_freq(CPU_BIG) == SCN.design.big_freq_ghz
    gov = SCN.replace(governor="userspace",
                      governor_params=(("freq_ghz", 1.0),)).make_governor()
    assert gov.initial_freq(CPU_LITTLE) == 1.0


# ------------------------------------------------- backend equivalence: run

def test_run_ref_matches_legacy_simulate():
    res = run(SCN, backend="ref")
    legacy = skr.simulate(SCN.soc(), SCN.applications(), SCN.job_trace(),
                          get_scheduler(SCN.scheduler))
    assert res.avg_latency_us == float(legacy.avg_job_latency_us)
    assert res.makespan_us == float(legacy.makespan_us)
    assert res.energy_j == float(legacy.energy.total_energy_j)
    assert res.throughput_jobs_per_ms == float(legacy.throughput_jobs_per_ms)
    np.testing.assert_array_equal(res.utilization,
                                  legacy.pe_utilization(SCN.soc()))


@pytest.mark.parametrize("policy", ["met", "etf", "table"])
def test_run_jax_bitexact_vs_legacy_entry_point(policy):
    scn = SCN.replace(scheduler=policy)
    res = run(scn, backend="jax")
    tables = skj.build_tables(scn.soc(), scn.applications(),
                              governor=scn.make_governor(),
                              table=scn.schedule_table())
    trace = scn.job_trace()
    legacy = skj.simulate_jax(tables, policy, trace.arrival_us,
                              trace.app_index)
    assert set(res.raw) == set(legacy)
    for key in legacy:
        np.testing.assert_array_equal(np.asarray(res.raw[key]),
                                      np.asarray(legacy[key]))


def test_run_backends_agree_on_metrics():
    for scn in (SCN, MIX, SCN.replace(scheduler="met")):
        ref = run(scn, backend="ref")
        jx = run(scn, backend="jax")
        np.testing.assert_allclose(jx.avg_latency_us, ref.avg_latency_us,
                                   rtol=1e-4)
        np.testing.assert_allclose(jx.energy_j, ref.energy_j, rtol=1e-3)


def test_result_metrics_surface():
    for backend in ("ref", "jax"):
        res = run(SCN, backend=backend)
        assert isinstance(res, Result)
        assert res.utilization.shape == (SCN.design.num_pes,)
        assert res.throughput_jobs_per_ms > 0
        assert res.peak_temp_c >= 25.0 - 1e-6       # >= ambient
        assert res.energy_j > 0 and res.avg_power_w > 0


def test_run_jax_rejects_ref_only_features():
    with pytest.raises(ValueError, match="backend"):
        run(SCN, backend="gem5")
    # fail-stop injection is no longer ref-only (DESIGN.md §14) — it only
    # defers to ref for the pinned offline-table scheduler
    res = run(SCN.replace(failures=(FaultSpec(0, 100.0),)), backend="jax")
    assert res.makespan_us > 0
    with pytest.raises(ValueError, match="table"):
        run(SCN.replace(scheduler="table",
                        failures=(FaultSpec(0, 100.0),)), backend="jax")
    # ondemand is no longer ref-only: the DTPM kernel runs it (DESIGN.md §7)
    res = run(SCN.replace(governor="ondemand"), backend="jax")
    assert res.makespan_us > 0 and res.peak_temp_c >= 25.0 - 1e-6


def test_run_ref_supports_failures_and_ondemand():
    res = run(SCN.replace(failures=(FaultSpec(0, 50.0),),
                          governor="ondemand"),
              backend="ref")
    assert res.makespan_us > 0
    assert not any(r.pe_id == 0 and r.finish_us > 50.0
                   for r in res.raw.records)


# ------------------------------------------------------------------- sweep

def test_sweep_two_axes_matches_run_in_one_compiled_program():
    points = [DesignPoint(4, 4, 2, 4, 0), DesignPoint(1, 2, 0, 1, 0),
              DesignPoint(0, 4, 1, 2, 1, big_freq_ghz=1.4)]
    rates = [5.0, 40.0]
    n0 = compile_count.value
    sr = sweep(MIX, axes={"rate": rates, "design": points})
    assert compile_count.value - n0 <= 1       # ONE program (0 if cache-warm)
    assert sr.shape == (2, 3) and sr.avg_latency_us.shape == (2, 3)
    for i, rate in enumerate(rates):
        for d, p in enumerate(points):
            ref = run(MIX.at_rate(rate).replace(design=p), backend="jax")
            assert sr.avg_latency_us[i, d] == ref.avg_latency_us
            assert sr.makespan_us[i, d] == ref.makespan_us
            assert sr.energy_j[i, d] == ref.energy_j
            assert np.all(sr.busy_per_pe_us[i, d, :p.num_pes]
                          == np.asarray(ref.raw["busy_per_pe_us"]))
            assert np.all(sr.busy_per_pe_us[i, d, p.num_pes:] == 0)


def test_sweep_repeat_call_hits_jit_cache():
    axes = {"rate": [5.0, 40.0], "seed": [0, 1]}
    sweep(MIX, axes=axes)
    n0 = compile_count.value
    sweep(MIX, axes=axes)
    assert compile_count.value == n0


def test_sweep_scheduler_axis_is_static():
    n0 = compile_count.value
    sr = sweep(SCN, axes={"scheduler": ["met", "etf"], "rate": [5.0, 40.0]})
    assert sr.shape == (2, 2)
    assert compile_count.value - n0 <= 2       # one program per policy
    for j, rate in enumerate([5.0, 40.0]):
        ref = run(SCN.replace(scheduler="met").at_rate(rate), backend="jax")
        assert sr.avg_latency_us[0, j] == ref.avg_latency_us


def test_sweep_design_times_governor_axes():
    """The ROADMAP DTPM direction: governor axes over design batches."""
    points = [DesignPoint(4, 4, 2, 4, 0), DesignPoint(1, 2, 0, 1, 0)]
    sr = sweep(MIX, axes={"design": points,
                          "governor": ["performance", "powersave"]})
    assert sr.shape == (2, 2)
    for d, p in enumerate(points):
        for g, gov in enumerate(["performance", "powersave"]):
            ref = run(MIX.replace(design=p, governor=gov), backend="jax")
            assert sr.avg_latency_us[d, g] == ref.avg_latency_us


def test_sweep_design_batch_validation():
    from repro.dse.batch import build_design_batch
    points = [DesignPoint(2, 2, 1, 1, 0)]
    batch = build_design_batch(points, MIX.applications())
    with pytest.raises(ValueError, match="governor='design'"):
        sweep(MIX, axes={"design": points, "seed": [0]}, design_batch=batch)
    with pytest.raises(ValueError, match="application list"):
        sweep(SCN.replace(governor="design"),
              axes={"design": points, "seed": [0]}, design_batch=batch)


def test_sweep_frequency_cap_axis():
    sr = sweep(MIX.replace(governor="design"),
               axes={"design.big_freq_ghz": [1.4, 2.0], "seed": [0, 1]})
    assert sr.shape == (2, 2)
    # lower frequency cap -> no faster than nominal
    assert np.all(sr.avg_latency_us[0] >= sr.avg_latency_us[1] - 1e-6)


def test_sweep_ref_backend_matches_run():
    sr = sweep(SCN, axes={"rate": [5.0, 40.0], "seed": [0, 1]},
               backend="ref")
    ref = run(SCN.at_rate(40.0).with_seed(1), backend="ref")
    assert sr.avg_latency_us[1, 1] == ref.avg_latency_us
    assert sr.peak_temp_c[1, 1] == ref.peak_temp_c


def test_sweep_explicit_trace_axis_matches_spec_axis():
    specs = [dataclasses.replace(SCN.trace, seed=s) for s in (0, 1)]
    traces = [s.materialize(SCN.app_names()) for s in specs]
    a = sweep(SCN, axes={"trace": specs})
    b = sweep(SCN, axes={"trace": traces})
    np.testing.assert_array_equal(a.avg_latency_us, b.avg_latency_us)


def test_sweep_validates_axes():
    with pytest.raises(ValueError, match="unknown sweep axis"):
        sweep(SCN, axes={"voltage": [1.0]})
    with pytest.raises(ValueError, match="at least one"):
        sweep(SCN, axes={})
    with pytest.raises(ValueError, match="equal job counts"):
        sweep(SCN, axes={"jobs": [8, 16]})
    # but a ref-backend jobs sweep works (the error message points there)
    sr = sweep(SCN, axes={"jobs": [8, 16]}, backend="ref")
    assert sr.shape == (2,)
    with pytest.raises(ValueError, match="duplicate sweep axes"):
        sweep(SCN, axes={"seed": [0, 1], "trace.seed": [2, 3]})
    with pytest.raises(ValueError, match="conflicts"):
        sweep(SCN, axes={"seed": [0, 1], "trace": [SCN.trace]})
    with pytest.raises(ValueError, match="conflicts"):
        sweep(SCN, axes={"design": [SCN.design],
                         "design.big_freq_ghz": [1.4, 2.0]})


# ------------------------------------------------------- deprecation shims

def test_core_simulate_shim_warns_and_matches():
    with pytest.warns(DeprecationWarning, match="repro.scenario"):
        legacy = core.simulate(SCN.soc(), SCN.applications(),
                               SCN.job_trace(), get_scheduler("etf"))
    assert run(SCN, backend="ref").avg_latency_us \
        == float(legacy.avg_job_latency_us)


def test_core_simulate_jax_shim_warns_matches_and_aliases():
    tables = skj.build_tables(SCN.soc(), SCN.applications())
    trace = SCN.job_trace()
    with pytest.warns(DeprecationWarning, match="repro.scenario"):
        out = core.simulate_jax(tables, "etf", trace.arrival_us,
                                trace.app_index)
    assert "energy_mj" not in out          # one-release alias key removed
    res = run(SCN, backend="jax")
    np.testing.assert_array_equal(np.asarray(out["avg_job_latency_us"]),
                                  res.avg_latency_us)


def test_dse_simulate_design_batch_shim_warns_and_matches():
    points = [DesignPoint(2, 2, 1, 1, 0)]
    batch = build_design_batch(points, MIX.applications())
    arrival, app_idx = stack_traces([MIX.job_trace()])
    with pytest.warns(DeprecationWarning, match="repro.scenario"):
        out = dse.simulate_design_batch(batch, "etf", arrival, app_idx)
    assert "energy_mj" not in out          # one-release alias key removed
    sr = sweep(MIX.replace(governor="design"),
               axes={"design": points, "seed": [MIX.trace.seed]})
    assert np.asarray(out["avg_job_latency_us"])[0, 0] \
        == sr.avg_latency_us[0, 0]


def test_energy_mj_aliases_removed():
    """The one-release *_mj deprecation window is over: aliases are gone."""
    report = run(SCN, backend="ref").energy_report
    assert not hasattr(report, "total_energy_mj")
    assert not hasattr(report, "energy_per_pe_mj")
    assert report.total_energy_j > 0
    ev = dse.evaluate([DesignPoint(2, 2, 1, 1, 0)], MIX.applications(),
                      [MIX.job_trace()])
    assert not hasattr(ev, "energy_mj")
    assert np.all(ev.energy_j > 0)


# ----------------------------------------------------- facade delegation

def test_dse_evaluate_equals_sweep():
    points = [DesignPoint(4, 4, 2, 4, 0), DesignPoint(1, 2, 0, 1, 0)]
    traces = [MIX.with_seed(s).job_trace() for s in (0, 1, 2)]
    ev = dse.evaluate(points, MIX.applications(), traces, policy="etf")
    sr = sweep(MIX.replace(governor="design"),
               axes={"design": points, "seed": [0, 1, 2]})
    np.testing.assert_array_equal(ev.latency_per_trace_us,
                                  sr.avg_latency_us)
    np.testing.assert_array_equal(ev.energy_per_trace_j, sr.energy_j)
    np.testing.assert_array_equal(ev.temp_per_trace_c, sr.peak_temp_c)


def test_sweep_iter_records():
    sr = sweep(SCN, axes={"rate": [5.0, 40.0], "seed": [0]})
    recs = list(sr.iter_records())
    assert len(recs) == 2
    coords, metrics = recs[1]
    assert coords == {"rate": 40.0, "seed": 0}
    assert metrics["avg_latency_us"] == sr.avg_latency_us[1, 0]
