"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, reduced
from repro.models import build_model

ARCH_IDS = sorted(ARCHITECTURES)
B, S = 2, 64


def make_batch(cfg, rng, batch=B, seq=S):
    t = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    batch_d = {"tokens": t, "labels": jnp.roll(t, -1, axis=1)}
    if cfg.frontend == "vision":
        batch_d["patch_embeds"] = jax.random.normal(
            rng, (batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        batch_d["frames"] = jax.random.normal(
            rng, (batch, seq, cfg.d_model), jnp.float32)
    return batch_d


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    return request.param, cfg, model, params, batch


def test_forward_shapes_and_finiteness(arch_setup):
    name, cfg, model, params, batch = arch_setup
    logits = jax.jit(model.forward_logits)(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"
    # padded vocab columns masked to -inf
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e20


def test_train_step_decreases_loss(arch_setup):
    name, cfg, model, params, batch = arch_setup
    loss_g = jax.jit(jax.value_and_grad(model.loss_fn))
    l0, g = loss_g(params, batch)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                         for x in jax.tree.leaves(g)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one SGD step moves the loss down
    lr = 0.05 / max(float(gnorm), 1.0)
    p2 = jax.tree.map(lambda p, gg: (p.astype(jnp.float32)
                                     - lr * gg.astype(jnp.float32)
                                     ).astype(p.dtype), params, g)
    l1 = jax.jit(model.loss_fn)(p2, batch)
    assert float(l1) < float(l0), f"{name}: loss {l0} -> {l1}"


def test_prefill_then_decode_matches_forward(arch_setup):
    """Greedy-decode consistency: logits from (prefill + decode steps) must
    match the teacher-forced forward logits position by position."""
    name, cfg, model, params, batch = arch_setup
    max_len = S + 8
    full = jax.jit(model.forward_logits)(params, batch)        # (B,S,V)

    n_pre = S - 4                                              # prefill split
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :n_pre]
    pre_batch.pop("labels")
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len)
                            )(params, pre_batch)
    # vlm: forward logits cover text positions only (prefix stripped)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full[:, n_pre - 1], np.float32), rtol=2e-2, atol=2e-2)

    decode = jax.jit(model.decode_step)
    offset = cfg.num_prefix_tokens if cfg.frontend == "vision" else 0
    for i in range(n_pre, S):
        tok = batch["tokens"][:, i:i + 1]
        logits, cache = decode(params, cache, tok, jnp.int32(i + offset))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full[:, i], np.float32), rtol=2e-2, atol=2e-2)


def test_full_configs_have_exact_paper_dims():
    """Full (non-reduced) configs carry the exact assigned dimensions."""
    c = get_config("deepseek-moe-16b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff) == (28, 2048, 16, 1408)
    assert (c.num_experts, c.top_k, c.num_shared_experts) == (64, 6, 2)
    assert c.vocab_size == 102_400
    c = get_config("dbrx-132b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (40, 6144, 48, 8)
    assert (c.num_experts, c.top_k) == (16, 4)
    c = get_config("gemma2-2b")
    assert c.block_pattern == ("local", "global")
    assert (c.attn_softcap, c.final_softcap) == (50.0, 30.0)
    assert c.vocab_size == 256_000
    c = get_config("recurrentgemma-2b")
    assert c.block_pattern == ("rglru", "rglru", "local")
    assert c.num_layers == 26 and c.d_model == 2560
    c = get_config("mamba2-130m")
    assert c.ssm_state == 128 and c.num_layers == 24 and c.d_model == 768
    c = get_config("seamless-m4t-large-v2")
    assert c.is_encoder_decoder and c.num_encoder_layers == 24
    assert c.d_model == 1024 and c.vocab_size == 256_206
    c = get_config("paligemma-3b")
    assert c.num_prefix_tokens == 256 and c.vocab_size == 257_216
    c = get_config("granite-3-8b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (40, 4096, 12_800, 49_155)
    c = get_config("starcoder2-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff) == (32, 4608, 36, 18_432)
    c = get_config("mistral-nemo-12b")
    assert (c.num_layers, c.d_model, c.head_dim, c.vocab_size) == (40, 5120, 128, 131_072)


def test_param_counts_full_configs():
    """Sanity: full-config parameter counts land near the advertised sizes."""
    expected = {                      # (arch, billions, rel tolerance)
        "mamba2-130m": (0.13, 0.5),
        "gemma2-2b": (2.6, 0.35),     # incl. 256k-vocab embeddings
        "granite-3-8b": (8.0, 0.3),
        "mistral-nemo-12b": (12.0, 0.3),
        "deepseek-moe-16b": (16.4, 0.3),
    }
    for name, (bn, tol) in expected.items():
        from repro.models import build_model
        n = build_model(get_config(name)).param_count() / 1e9
        assert abs(n - bn) / bn < tol, f"{name}: {n:.2f}B vs {bn}B"
