"""Power, thermal and DVFS-governor model tests."""
import numpy as np
import pytest

from repro.core import (active_power, get_governor, get_scheduler, idle_power,
                        make_soc_table2, poisson_trace, thermal, wifi_tx)
from repro.core.simkernel_ref import simulate
from repro.core.resources import CPU_BIG, CPU_LITTLE, OPP_TABLE


def test_power_monotone_in_frequency():
    db = make_soc_table2()
    big = db.pes_of_type(CPU_BIG)[0]
    lit = db.pes_of_type(CPU_LITTLE)[0]
    for pe in (big, lit):
        freqs = [f for f, _ in OPP_TABLE[pe.pe_type]]
        powers = [active_power(pe, f) for f in freqs]
        assert all(b > a for a, b in zip(powers, powers[1:]))
    # big core burns more than LITTLE at max frequency
    assert active_power(big, 2.0) > active_power(lit, 1.4)
    assert idle_power(big) < active_power(big, 0.6)


def test_governors_initial_frequencies():
    assert get_governor("performance").initial_freq(CPU_BIG) == 2.0
    assert get_governor("powersave").initial_freq(CPU_BIG) == 0.6
    assert get_governor("userspace", freq_ghz=1.4).initial_freq(CPU_BIG) == 1.4
    od = get_governor("ondemand")
    assert od.initial_freq(CPU_BIG) == 0.6
    assert od.update(CPU_BIG, 0.6, utilization=0.95) == 2.0   # busy -> fmax
    assert od.update(CPU_BIG, 2.0, utilization=0.05) < 2.0    # idle -> down


def test_ondemand_threshold_transitions():
    od = get_governor("ondemand", up_threshold=0.8, sample_window_us=50.0)
    big_opps = [f for f, _ in OPP_TABLE[CPU_BIG]]
    # above up_threshold: jump straight to fmax from any frequency
    assert od.update(CPU_BIG, 0.6, utilization=0.81) == big_opps[-1]
    assert od.update(CPU_BIG, 1.4, utilization=1.0) == big_opps[-1]
    assert od.update(CPU_LITTLE, 0.6, utilization=0.9) \
        == OPP_TABLE[CPU_LITTLE][-1][0]
    # at/below the threshold: proportional step-down to the smallest OPP
    # covering target = fmax * util / up_threshold
    assert od.update(CPU_BIG, 2.0, utilization=0.0) == big_opps[0]   # fmin
    assert od.update(CPU_BIG, 2.0, utilization=0.4) == 1.0   # 2.0*0.4/0.8
    assert od.update(CPU_BIG, 2.0, utilization=0.5) == 1.4   # 1.25 -> 1.4
    assert od.update(CPU_BIG, 1.0, utilization=0.8) == 2.0   # target fmax
    # custom threshold changes the proportional mapping
    od2 = get_governor("ondemand", up_threshold=0.5)
    assert od2.update(CPU_BIG, 2.0, utilization=0.25) == 1.0


def test_userspace_per_type_dict_vs_scalar():
    scalar = get_governor("userspace", freq_ghz=1.0)
    assert scalar.initial_freq(CPU_BIG) == 1.0
    assert scalar.initial_freq(CPU_LITTLE) == 1.0
    per_type = get_governor("userspace",
                            freq_ghz={CPU_BIG: 1.8, CPU_LITTLE: 0.8})
    assert per_type.initial_freq(CPU_BIG) == 1.8
    assert per_type.initial_freq(CPU_LITTLE) == 0.8
    with pytest.raises(KeyError):
        get_governor("userspace", freq_ghz={CPU_BIG: 1.8}) \
            .initial_freq(CPU_LITTLE)
    # static governor: update() never moves the frequency
    assert per_type.update(CPU_BIG, 1.8, utilization=0.99) == 1.8
    assert scalar.update(CPU_LITTLE, 1.0, utilization=0.0) == 1.0


def test_powersave_slower_but_sim_still_correct():
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(2.0, 40, ["wifi_tx"], seed=0)
    perf = simulate(db, [app], trace, get_scheduler("etf"),
                    get_governor("performance"))
    save = simulate(db, [app], trace, get_scheduler("etf"),
                    get_governor("powersave"))
    assert save.avg_job_latency_us > perf.avg_job_latency_us
    # powersave spends less energy on the CPU portion; with fixed-latency
    # accelerators dominating idle leakage the total can still drop
    assert save.energy.total_energy_j < perf.energy.total_energy_j * 1.5


def test_ondemand_ramps_under_load():
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(60.0, 300, ["wifi_tx"], seed=0)
    res = simulate(db, [app], trace, get_scheduler("etf"),
                   get_governor("ondemand", sample_window_us=50.0))
    freqs = [r.freq_ghz for r in res.records
             if db.pes[r.pe_id].pe_type == CPU_BIG]
    assert freqs, "no big-core tasks scheduled"
    assert min(freqs) == 0.6          # starts at fmin
    assert max(freqs) == 2.0          # ramps to fmax under load


def test_thermal_convergence_to_steady_state():
    p = np.array([3.0, 0.5, 0.8])
    trace = np.tile(p, (400_000, 1))
    temps = thermal.simulate_trace(trace, dt_s=0.001)
    expect = thermal.steady_state(p)
    np.testing.assert_allclose(temps[-1], expect, rtol=1e-2)
    assert np.all(np.diff(temps[:, 0]) >= -1e-9)   # monotone heat-up


def test_thermal_hotter_with_more_power():
    lo = thermal.simulate_trace(np.tile([1.0, 0.2, 0.2], (50_000, 1)), 0.001)
    hi = thermal.simulate_trace(np.tile([4.0, 0.2, 0.2], (50_000, 1)), 0.001)
    assert hi[-1, 0] > lo[-1, 0]
    assert hi[-1, 3] > lo[-1, 3]
