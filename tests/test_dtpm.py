"""Closed-loop DTPM in the JAX kernel (DESIGN.md §7, §10).

The dynamic-DVFS equivalence contract extends the static one: on comm-free
integer-latency workloads the epoch-scan DTPM kernel reproduces the
event-heap reference *bit for bit* under the ondemand governor — same
schedules, same latched frequencies — because both kernels execute the same
array-form ``GovernorPolicy`` transition (``dvfs.ondemand_index`` /
``throttle_index``).  On top: governor-transition property tests, the
thermal-throttle cap bound, and the one-program-per-policy-shape sweep
contract with per-policy peak temperature from the inline RC loop.
"""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core.applications import wifi_tx
from repro.core.dvfs import (GovernorPolicy, MAX_OPP_LEVELS, OndemandGovernor,
                             ThrottleGovernor, get_governor, ondemand_index,
                             padded_ladder, stack_policies, throttle_index)
from repro.core.jobgen import deterministic_trace, poisson_trace
from repro.core.resources import (CPU_BIG, CPU_LITTLE, OPP_TABLE, CommModel,
                                  make_soc_table2)
from repro.core.schedulers import get_scheduler
from repro.core.simkernel_jax import build_tables, simulate_jax_dtpm
from repro.core.simkernel_ref import simulate
from repro.dse import DesignPoint, build_design_batch, evaluate
from repro.scenario import Scenario, TraceSpec, run, sweep, tables_for
from repro.scenario.sweep import compile_count

SCN = Scenario(apps=("wifi_tx",),
               trace=TraceSpec(rate_jobs_per_ms=25.0, num_jobs=24, seed=3))


def _comm_free_db():
    db = make_soc_table2()
    db.comm = CommModel(startup_us=0.0, bw_bytes_per_us=1e30)
    return db


# ------------------------------------------------ ref <-> jax equivalence

@pytest.mark.parametrize("policy", ["met", "etf"])
def test_ondemand_bitexact_on_tier1_trace(policy):
    """Comm-free integer latencies => bit-exact DTPM schedules in float32:
    the static exact-equality contract extended to the ondemand governor."""
    db = _comm_free_db()
    app = wifi_tx()
    trace = deterministic_trace(25.0, 64, ["wifi_tx"])
    gov = OndemandGovernor(sample_window_us=50.0)
    ref = simulate(db, [app], trace, get_scheduler(policy), gov)
    tables = build_tables(db, [app], governor=gov)
    jx = simulate_jax_dtpm(tables, policy, trace.arrival_us, trace.app_index,
                           gov.policy())
    fin = np.asarray(jx["finish"])
    onpe = np.asarray(jx["onpe"])
    onopp = np.asarray(jx["onopp"])
    opp_freq = np.asarray(tables.opp_freq)
    pe_domain = np.asarray(tables.pe_domain)
    assert ref.records, "empty schedule"
    for r in ref.records:
        assert fin[r.job_id, r.task_id] == np.float32(r.finish_us)
        assert onpe[r.job_id, r.task_id] == r.pe_id
        if db.pes[r.pe_id].is_cpu:
            # the latched DVFS frequency agrees decision-for-decision
            f = opp_freq[pe_domain[r.pe_id], onopp[r.job_id, r.task_id]]
            assert f == np.float32(r.freq_ghz)


@pytest.mark.parametrize("rate,seed", [(60.0, 0), (20.0, 3)])
def test_ondemand_kernels_agree_with_comm(rate, seed):
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(rate, 100, ["wifi_tx"], seed=seed)
    gov = OndemandGovernor()
    ref = simulate(db, [app], trace, get_scheduler("etf"), gov)
    tables = build_tables(db, [app], governor=gov)
    jx = simulate_jax_dtpm(tables, "etf", trace.arrival_us, trace.app_index,
                           gov.policy())
    np.testing.assert_allclose(float(jx["avg_job_latency_us"]),
                               ref.avg_job_latency_us, rtol=1e-4)
    np.testing.assert_allclose(float(jx["makespan_us"]), ref.makespan_us,
                               rtol=1e-4)
    np.testing.assert_allclose(float(jx["energy_j"]),
                               ref.energy.total_energy_j, rtol=1e-3)


def test_run_facade_ondemand_backends_agree():
    scn = SCN.replace(governor="ondemand")
    jx = run(scn, backend="jax")
    ref = run(scn, backend="ref")
    np.testing.assert_allclose(jx.avg_latency_us, ref.avg_latency_us,
                               rtol=1e-4)
    np.testing.assert_allclose(jx.energy_j, ref.energy_j, rtol=1e-3)


def test_ondemand_ramps_in_jax_kernel():
    """Under load the compiled kernel leaves fmin — the loop really closes."""
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(60.0, 300, ["wifi_tx"], seed=0)
    gov = OndemandGovernor()
    tables = build_tables(db, [app], governor=gov)
    jx = simulate_jax_dtpm(tables, "etf", trace.arrival_us, trace.app_index,
                           gov.policy())
    onopp = np.asarray(jx["onopp"])
    big = [j for j, pe in enumerate(db.pes) if pe.pe_type == CPU_BIG]
    mask = np.isin(np.asarray(jx["onpe"]), big) & np.asarray(jx["scheduled"])
    assert onopp[mask].min() == 0                       # starts at fmin
    assert onopp[mask].max() == len(OPP_TABLE[CPU_BIG]) - 1   # reaches fmax


# ------------------------------------------------ governor-transition laws

def test_ondemand_index_matches_object_governor():
    gov = OndemandGovernor(up_threshold=0.8)
    for pe_type in (CPU_BIG, CPU_LITTLE):
        opps = [f for f, _ in OPP_TABLE[pe_type]]
        for u in np.linspace(0.0, 1.2, 61):
            f = gov.update(pe_type, opps[0], float(u))
            assert f in opps                       # OPP-set closure


@given(u1=st.floats(min_value=0.0, max_value=1.5),
       u2=st.floats(min_value=0.0, max_value=1.5),
       up=st.sampled_from([0.5, 0.8, 0.95, 1.0]),
       pe_type=st.sampled_from([CPU_BIG, CPU_LITTLE]))
@settings(max_examples=80, deadline=None)
def test_property_ondemand_monotone_and_closed(u1, u2, up, pe_type):
    """util -> freq is monotone non-decreasing, and always lands in the
    OPP set (the two invariants the array-form transition must keep)."""
    opps, padded, count = padded_ladder(pe_type)
    row, n = np.asarray([padded]), np.asarray([count])
    lo, hi = sorted([u1, u2])
    i_lo = int(ondemand_index(row, n, up, np.asarray([lo]))[0])
    i_hi = int(ondemand_index(row, n, up, np.asarray([hi]))[0])
    assert i_lo <= i_hi                            # monotone in utilisation
    for i in (i_lo, i_hi):
        assert 0 <= i < len(opps)                  # OPP-set closure
        assert row[0, i] in opps


def test_throttle_index_clamps_hot_domains():
    idx = np.asarray([4, 2, 0])
    temps = np.asarray([80.0, 40.0, 90.0])
    out = throttle_index(idx, temps, 60.0)
    np.testing.assert_array_equal(out, [0, 2, 0])
    # infinite cap disables the override
    np.testing.assert_array_equal(throttle_index(idx, temps, np.inf), idx)


def test_kernel_table_and_window_guards():
    """Mismatched tables/kernel and degenerate windows fail fast instead of
    silently computing fmin-pinned results or hanging the window loop."""
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(20.0, 8, ["wifi_tx"], seed=0)
    dyn_tables = build_tables(db, [app], governor=OndemandGovernor())
    from repro.core.simkernel_jax import simulate_jax
    with pytest.raises(ValueError, match="dynamic governor"):
        simulate_jax(dyn_tables, "etf", trace.arrival_us, trace.app_index)
    static_tables = build_tables(db, [app])
    with pytest.raises(ValueError, match="OPP ladders"):
        simulate_jax_dtpm(static_tables, "etf", trace.arrival_us,
                          trace.app_index, OndemandGovernor().policy())
    with pytest.raises(ValueError, match="positive"):
        OndemandGovernor(sample_window_us=0.0)
    with pytest.raises(ValueError, match="positive"):
        simulate_jax_dtpm(dyn_tables, "etf", trace.arrival_us,
                          trace.app_index,
                          GovernorPolicy(dynamic=True, sample_window_us=0.0))
    with pytest.raises(ValueError, match="positive"):
        stack_policies([GovernorPolicy(dynamic=True, sample_window_us=-1.0)])
    with pytest.raises(ValueError, match="up_threshold"):
        OndemandGovernor(up_threshold=0.0)
    with pytest.raises(ValueError, match="up_threshold"):
        stack_policies([GovernorPolicy(dynamic=True, up_threshold=0.0)])
    with pytest.raises(ValueError, match="dynamic"):
        build_design_batch([DesignPoint(2, 2, 1, 1, 0)], [app],
                           governor=get_governor("performance"))


def test_governor_registry_and_policies():
    assert get_governor("throttle").policy().dynamic
    assert np.isfinite(get_governor("throttle").policy().thermal_cap_c)
    assert not get_governor("performance").policy().dynamic
    assert get_governor("ondemand").policy().dynamic
    assert not np.isfinite(get_governor("ondemand").policy().thermal_cap_c)
    with pytest.raises(ValueError, match="dynamic"):
        stack_policies([GovernorPolicy(dynamic=False)])


# ------------------------------------------------ thermal-throttle bound

def test_throttle_cap_bounds_peak_temperature():
    """Peak temperature under a cap never exceeds cap + one window of slack
    (the throttle reacts one sampling window after the crossing)."""
    scn = SCN.replace(**{"trace.rate_jobs_per_ms": 60.0,
                         "trace.num_jobs": 300, "trace.seed": 0})
    params = (("sample_window_us", 50.0), ("thermal_dt_s", 0.2))
    free = run(scn.replace(governor="ondemand", governor_params=params),
               backend="jax")
    cap = 30.0
    capped = run(scn.replace(
        governor="ondemand",
        governor_params=params + (("thermal_cap_c", cap),)), backend="jax")
    assert free.peak_temp_c > cap          # the cap binds on this workload
    assert capped.peak_temp_c <= cap + 3.0       # one-window overshoot slack
    assert capped.peak_temp_c < free.peak_temp_c
    # throttling trades latency for temperature
    assert capped.avg_latency_us >= free.avg_latency_us


def test_throttle_ref_kernel_agrees():
    """The reference kernel runs the same closed loop (thin wrappers over
    the shared policy step): results agree to float tolerance."""
    scn = SCN.replace(**{"trace.rate_jobs_per_ms": 60.0,
                         "trace.num_jobs": 300, "trace.seed": 0},
                      governor="ondemand",
                      governor_params=(("sample_window_us", 50.0),
                                       ("thermal_dt_s", 0.2),
                                       ("thermal_cap_c", 30.0)))
    jx = run(scn, backend="jax")
    ref = run(scn, backend="ref")
    np.testing.assert_allclose(jx.avg_latency_us, ref.avg_latency_us,
                               rtol=1e-4)


# ------------------------------------------------ policy sweeps (§10)

def test_sweep_32_policies_one_program_with_inline_peak_temp():
    """≥32 governor_params points: ONE compiled program per policy shape,
    per-policy peak temperature reported from the inline RC loop."""
    params = [(("up_threshold", u), ("sample_window_us", w))
              for u in (0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 1.0)
              for w in (25.0, 50.0, 100.0, 200.0)]
    assert len(params) == 32
    scn = SCN.replace(governor="ondemand")
    n0 = compile_count.value
    sr = sweep(scn, axes={"governor_params": params})
    assert compile_count.value - n0 <= 1       # ONE program (0 if cache-warm)
    assert sr.shape == (32,)
    assert sr.peak_temp_c.shape == (32,)
    assert np.all(np.isfinite(sr.peak_temp_c))
    assert np.all(sr.peak_temp_c >= 25.0 - 1e-6)
    # every lane equals its single-scenario run()
    for k in (0, 13, 31):
        single = run(scn.replace(governor_params=params[k]), backend="jax")
        assert sr.avg_latency_us[k] == single.avg_latency_us
        assert sr.energy_j[k] == single.energy_j
        np.testing.assert_allclose(sr.peak_temp_c[k], single.peak_temp_c,
                                   rtol=1e-5)


def test_sweep_policy_times_design_times_trace():
    points = [DesignPoint(4, 4, 2, 4, 0), DesignPoint(1, 2, 0, 1, 0)]
    params = [(("up_threshold", 0.6),), (("up_threshold", 0.9),)]
    sr = sweep(SCN.replace(governor="ondemand"),
               axes={"design": points, "governor_params": params,
                     "seed": [0, 1]})
    assert sr.shape == (2, 2, 2)
    for d, p in enumerate(points):
        single = run(SCN.replace(governor="ondemand", design=p,
                                 governor_params=params[1]).with_seed(1),
                     backend="jax")
        assert sr.avg_latency_us[d, 1, 1] == single.avg_latency_us


def test_sweep_mixed_governor_kinds_rejected():
    with pytest.raises(ValueError, match="policy[ \n]+shapes|policy shapes"):
        sweep(SCN, axes={"governor": ["performance", "ondemand"]})


def test_sweep_ref_backend_governor_params():
    params = [(("up_threshold", 0.6),), (("up_threshold", 0.9),)]
    sr = sweep(SCN.replace(governor="ondemand"),
               axes={"governor_params": params}, backend="ref")
    single = run(SCN.replace(governor="ondemand",
                             governor_params=params[1]), backend="ref")
    assert sr.avg_latency_us[1] == single.avg_latency_us


# ------------------------------------------------ DSE over dynamic policies

def test_design_batch_gains_opp_dimension():
    points = [DesignPoint(4, 4, 2, 4, 0),
              DesignPoint(2, 2, 1, 2, 0, big_freq_ghz=1.4)]
    apps = [wifi_tx()]
    static = build_design_batch(points, apps)
    assert not static.dynamic and static.tables.exec_opp is None
    dyn = build_design_batch(points, apps, governor=OndemandGovernor())
    assert dyn.dynamic
    D, A, T, P, K = dyn.tables.exec_opp.shape     # leading design axis
    assert (D, K) == (2, MAX_OPP_LEVELS)
    # the second design's big-cluster ladder is truncated at its 1.4 GHz cap
    num_opp = np.asarray(dyn.tables.num_opp)
    big_levels = [f for f, _ in OPP_TABLE[CPU_BIG]]
    assert num_opp[0, 0] == len(big_levels)
    assert num_opp[1, 0] == sum(f <= 1.4 + 1e-9 for f in big_levels)


def test_dynamic_governor_respects_design_freq_caps():
    """A design's frequency caps bound the ondemand ladder on every entry
    point — run(), sweep lanes and dse.evaluate agree on the capped set."""
    point = DesignPoint(4, 4, 2, 4, 0, big_freq_ghz=1.0)
    scn = SCN.replace(design=point, governor="ondemand",
                      **{"trace.rate_jobs_per_ms": 60.0,
                         "trace.num_jobs": 120})
    res = run(scn, backend="jax")
    tables = tables_for(scn)
    big_levels = [f for f, _ in OPP_TABLE[CPU_BIG]]
    capped = sum(f <= 1.0 + 1e-9 for f in big_levels)
    assert int(np.asarray(tables.num_opp)[0]) == capped
    # the latched OPP never exceeds the cap on big-cluster tasks
    onopp = np.asarray(res.raw["onopp"])
    onpe = np.asarray(res.raw["onpe"])
    big = [j for j, pe in enumerate(scn.soc().pes) if pe.pe_type == CPU_BIG]
    mask = np.isin(onpe, big) & np.asarray(res.raw["scheduled"])
    assert onopp[mask].max() <= capped - 1
    # the reference kernel ranges over the same capped ladder
    ref = run(scn, backend="ref")
    assert max(r.freq_ghz for r in ref.raw.records
               if scn.soc().pes[r.pe_id].pe_type == CPU_BIG) <= 1.0 + 1e-9
    np.testing.assert_allclose(res.avg_latency_us, ref.avg_latency_us,
                               rtol=1e-4)
    # dse.evaluate's capped batch matches the facade numbers
    ev = evaluate([point], [wifi_tx()], [scn.job_trace()],
                  governor="ondemand")
    assert ev.latency_per_trace_us[0, 0] == res.avg_latency_us


def test_sweep_rejects_mismatched_design_batch_kind():
    points = [DesignPoint(2, 2, 1, 1, 0)]
    apps = [wifi_tx()]
    dyn_batch = build_design_batch(points, apps, governor=OndemandGovernor())
    # static sweep over dynamic-built tables (exec_us baked at fmin) — reject
    with pytest.raises(ValueError, match="dynamic governor"):
        sweep(SCN.replace(governor="design"),
              axes={"design": points, "seed": [0]}, design_batch=dyn_batch)
    # dynamic sweep over static-built tables (no OPP ladders) — reject
    static_batch = build_design_batch(points, apps)
    with pytest.raises(ValueError, match="OPP ladders"):
        sweep(SCN.replace(governor="ondemand"),
              axes={"design": points, "seed": [0]},
              design_batch=static_batch)


def test_dse_evaluate_ranks_dynamic_policies():
    points = [DesignPoint(4, 4, 2, 4, 0), DesignPoint(1, 2, 0, 1, 0)]
    apps = [wifi_tx()]
    traces = [poisson_trace(20.0, 16, ["wifi_tx"], seed=s) for s in (0, 1)]
    ev = evaluate(points, apps, traces, governor="ondemand",
                  governor_params=(("thermal_dt_s", 0.05),))
    assert ev.avg_latency_us.shape == (2,)
    assert np.all(np.isfinite(ev.objectives()))
    assert np.all(ev.peak_temp_c >= 25.0 - 1e-6)
    with pytest.raises(ValueError, match="design"):
        evaluate(points, apps, traces, governor="performance")
