"""Sharded + chunked sweep execution (DESIGN.md §13).

The lane-scaling contract: ``sweep(..., chunk=N)`` and ``sweep(...,
shard=True)`` are *bit-for-bit* equal to the plain single-device sweep —
including uneven lane counts (pad lanes are dropped) and telemetry replay —
because lanes are independent simulations and the chunk programs run the
same fused grid bodies.  Multi-device sharding is exercised in a subprocess
(the forced 8-device CPU topology must not leak into other tests).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dse import DesignPoint
from repro.obs import metrics
from repro.scenario import Scenario, TraceSpec, sweep
from repro.scenario import shardexec

SCN = Scenario(apps=("wifi_tx",), scheduler="etf", governor="design",
               trace=TraceSpec(rate_jobs_per_ms=25.0, num_jobs=16, seed=3))
POINTS = [DesignPoint(cross_cluster_penalty=1.0 + 0.5 * i) for i in range(5)]
FIELDS = ("avg_latency_us", "makespan_us", "energy_j", "peak_temp_c",
          "busy_per_pe_us")


def _assert_bitexact(a, b):
    for f in FIELDS:
        av, bv = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert np.array_equal(av, bv), f
    if a.telemetry is not None:
        assert b.telemetry is not None
        for ta, tb in zip(a.telemetry.ravel(), b.telemetry.ravel()):
            assert ta.num_windows == tb.num_windows
            assert np.array_equal(ta.util, tb.util)
            assert np.array_equal(ta.temps_c, tb.temps_c)
            assert np.array_equal(ta.freq_idx, tb.freq_idx)


# ------------------------------------------------ pad/width helpers

def test_padded_width_is_pinned():
    # no chunk: all lanes, rounded to the device quantum
    assert shardexec.padded_width(5, None, 1) == 5
    assert shardexec.padded_width(5, None, 8) == 8
    # chunk given: the width is chunk-derived, NOT lane-derived, so grids
    # of different lane counts share one jit cache entry
    assert shardexec.padded_width(5, 2, 1) == 2
    assert shardexec.padded_width(3, 2, 1) == 2
    assert shardexec.padded_width(5, 3, 2) == 4
    assert shardexec.padded_width(100, 8, 8) == 8


def test_pad_lane_axis_repeats_lane0():
    tree = {"a": np.arange(6.0).reshape(3, 2), "b": np.arange(3)}
    out = shardexec.pad_lane_axis(tree, 3, 5)
    assert out["a"].shape == (5, 2) and out["b"].shape == (5,)
    np.testing.assert_array_equal(out["a"][3], tree["a"][0])
    np.testing.assert_array_equal(out["a"][4], tree["a"][0])
    np.testing.assert_array_equal(out["a"][:3], tree["a"])
    # width == lanes is the identity (same object, no copy)
    assert shardexec.pad_lane_axis(tree, 3, 3) is tree


# ------------------------------------------------ chunked == plain (1 device)

def test_chunked_static_sweep_bitexact():
    """chunk=2 over 5 uneven design lanes: equal to the plain sweep, with
    the streaming counters accounting for every chunk and pad lane."""
    axes = {"design": POINTS, "seed": [0, 1]}
    plain = sweep(SCN, axes=axes)
    chunks = metrics.counter("scenario.sweep.chunks")
    pads = metrics.counter("scenario.shard.pad_lanes")
    c0, p0 = chunks.value, pads.value
    chunked = sweep(SCN, axes=axes, chunk=2)
    _assert_bitexact(plain, chunked)
    assert chunks.value - c0 == 3          # ceil(5 / 2)
    assert pads.value - p0 == 1            # last chunk holds 1 real lane
    assert metrics.counter("scenario.shard.devices").value == 1


def test_chunked_dtpm_sweep_bitexact_both_lane_axes():
    """The DTPM grid streams whichever lane axis is wider: the design axis
    (D >= G) and the stacked GovernorPolicy axis (G > D) both chunk clean."""
    scn = SCN.replace(governor="ondemand")
    params = [(("up_threshold", 0.5 + 0.08 * i),) for i in range(5)]
    # G=5 > D=1: policy lanes stream
    axes = {"governor_params": params, "seed": [0, 1]}
    _assert_bitexact(sweep(scn, axes=axes), sweep(scn, axes=axes, chunk=2))
    # D=3 > G=2: design lanes stream
    axes = {"design": POINTS[:3], "governor_params": params[:2],
            "seed": [0]}
    _assert_bitexact(sweep(scn, axes=axes), sweep(scn, axes=axes, chunk=2))


def test_chunked_telemetry_replay_bitexact():
    axes = {"design": POINTS[:3], "seed": [0]}
    _assert_bitexact(sweep(SCN, axes=axes, telemetry=True),
                     sweep(SCN, axes=axes, telemetry=True, chunk=2))
    scn = SCN.replace(governor="ondemand")
    axes = {"governor_params": [(("up_threshold", 0.6),),
                                (("up_threshold", 0.8),),
                                (("up_threshold", 0.9),)], "seed": [0]}
    _assert_bitexact(sweep(scn, axes=axes, telemetry=True),
                     sweep(scn, axes=axes, telemetry=True, chunk=2))


def test_chunk_shape_is_jit_stable():
    """Streaming more lanes through the same chunk width adds no compiles."""
    axes = {"design": POINTS, "seed": [0]}
    sweep(SCN, axes=axes, chunk=2)                         # traces once
    before = metrics.counter("scenario.sweep.compile_count").value
    sweep(SCN, axes={"design": POINTS[:3], "seed": [0]}, chunk=2)
    sweep(SCN, axes=axes, chunk=2)
    assert metrics.counter("scenario.sweep.compile_count").value == before


# ------------------------------------------------ argument validation

def test_chunk_validation():
    axes = {"design": POINTS[:2], "seed": [0]}
    with pytest.raises(ValueError, match="positive lane count"):
        sweep(SCN, axes=axes, chunk=0)
    with pytest.raises(ValueError, match="positive lane count"):
        sweep(SCN, axes=axes, chunk=2.5)
    with pytest.raises(ValueError, match="jax-backend lane options"):
        sweep(SCN, axes={"seed": [0]}, backend="ref", chunk=2)
    with pytest.raises(ValueError, match="jax-backend lane options"):
        sweep(SCN, axes={"seed": [0]}, backend="ref", shard=True)


def test_resolve_mesh_single_device():
    # one local device: no mesh — the chunked path runs unsharded
    assert shardexec.resolve_mesh(None) is None
    assert shardexec.resolve_mesh(True) is None
    assert shardexec.resolve_mesh(False) is None


# ------------------------------------------------ multi-device (subprocess)

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.dse import DesignPoint
    from repro.obs import metrics
    from repro.scenario import Scenario, TraceSpec, sweep

    assert jax.device_count() == 8
    SCN = Scenario(apps=("wifi_tx",), scheduler="etf", governor="design",
                   trace=TraceSpec(rate_jobs_per_ms=25.0, num_jobs=16,
                                   seed=3))
    points = [DesignPoint(cross_cluster_penalty=1.0 + 0.5 * i)
              for i in range(5)]
    axes = {"design": points, "seed": [0, 1]}
    FIELDS = ("avg_latency_us", "makespan_us", "energy_j", "peak_temp_c",
              "busy_per_pe_us")

    def check(a, b):
        for f in FIELDS:
            assert np.array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(b, f))), f
        if a.telemetry is not None:
            for ta, tb in zip(a.telemetry.ravel(), b.telemetry.ravel()):
                assert np.array_equal(ta.util, tb.util)
                assert np.array_equal(ta.temps_c, tb.temps_c)

    plain = sweep(SCN, axes=axes, shard=False)
    pads = metrics.counter("scenario.shard.pad_lanes")

    # 5 uneven lanes sharded over 8 devices: padded to 8, bit-for-bit
    check(plain, sweep(SCN, axes=axes))            # shard=None auto-shards
    assert metrics.counter("scenario.shard.devices").value == 8
    assert pads.value == 3                         # 5 lanes -> width 8

    # sharding composes with chunking (chunk=2 -> width 8 per chunk)
    check(plain, sweep(SCN, axes=axes, shard=True, chunk=2))

    # telemetry replays from sharded grid outputs unchanged
    check(sweep(SCN, axes=axes, shard=False, telemetry=True),
          sweep(SCN, axes=axes, shard=True, telemetry=True))

    # the DTPM policy-lane axis shards too
    scn = SCN.replace(governor="ondemand")
    paxes = {"governor_params": [(("up_threshold", 0.5 + 0.08 * i),)
                                 for i in range(5)], "seed": [0]}
    check(sweep(scn, axes=paxes, shard=False), sweep(scn, axes=paxes))
    print("SHARD_OK")
""")


def test_sharded_sweep_bitexact_8_virtual_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD_OK" in out.stdout, out.stdout
