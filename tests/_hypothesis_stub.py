"""Compat layer for ``hypothesis`` so its absence degrades gracefully.

Re-exports the real ``given``/``settings``/``st`` when hypothesis is
installed; otherwise provides shims under which ``@given``-decorated
property tests are skipped while the deterministic tests in the same module
still collect and run.  Install the real thing with ``pip install -e .[test]``.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    import pytest

    class _Stub:
        """Swallows any attribute access / call chain (st.integers().map())."""

        def __call__(self, *args, **kwargs):
            return _Stub()

        def __getattr__(self, name):
            return _Stub()

    st = _Stub()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
