"""Core simulator tests: paper fidelity (Tables 1-2, Fig 3) + invariants."""
import numpy as np
import pytest

from repro.core import (ACC_FFT, ACC_SCRAMBLER, CPU_BIG, CPU_LITTLE,
                        Application, Task, TableScheduler, available_schedulers,
                        build_tables, deterministic_trace, get_application,
                        get_scheduler, make_soc, make_soc_table2,
                        poisson_trace, solve_optimal_table, wifi_tx)
# kernels imported directly: the repro.core re-exports are deprecation shims
from repro.core.simkernel_jax import simulate_jax
from repro.core.simkernel_ref import simulate
from repro.core.resources import ALL_PROFILES, CommModel, ResourceDB


# ---------------------------------------------------------------- Table 1/2

def test_table1_wifi_tx_profiles():
    """Latency numbers must match paper Table 1 exactly."""
    p = ALL_PROFILES
    assert p["scrambler_encoder"] == {ACC_SCRAMBLER: 8, CPU_LITTLE: 22, CPU_BIG: 10}
    assert p["interleaver"] == {CPU_LITTLE: 10, CPU_BIG: 4}
    assert p["qpsk_modulation"] == {CPU_LITTLE: 15, CPU_BIG: 8}
    assert p["pilot_insertion"] == {CPU_LITTLE: 5, CPU_BIG: 3}
    assert p["inverse_fft"] == {ACC_FFT: 16, CPU_LITTLE: 296, CPU_BIG: 118}
    assert p["crc"] == {CPU_LITTLE: 5, CPU_BIG: 3}


def test_table2_soc_configuration():
    db = make_soc_table2()
    assert db.num_pes == 14
    assert len(db.pes_of_type(CPU_BIG)) == 4
    assert len(db.pes_of_type(CPU_LITTLE)) == 4
    assert len(db.pes_of_type(ACC_SCRAMBLER)) == 2
    assert len(db.pes_of_type(ACC_FFT)) == 4


def test_all_reference_apps_simulate():
    db = make_soc_table2(with_viterbi=True)
    names = ["wifi_tx", "wifi_rx", "single_carrier", "range_detection",
             "pulse_doppler"]
    apps = [get_application(n) for n in names]
    trace = poisson_trace(5.0, 40, names, seed=1)
    for sched in ["met", "etf"]:
        res = simulate(db, apps, trace, get_scheduler(sched))
        assert len(res.records) == sum(apps[int(i)].num_tasks
                                       for i in trace.app_index)
        assert res.avg_job_latency_us > 0
        assert res.energy.total_energy_j > 0


# ---------------------------------------------------------------- Fig 3

@pytest.fixture(scope="module")
def fig3_data():
    db = make_soc_table2()
    app = wifi_tx()
    table = solve_optimal_table(db, app)
    out = {}
    for rate in [1.0, 60.0]:
        for name, sched in [("met", get_scheduler("met")),
                            ("etf", get_scheduler("etf")),
                            ("ilp", TableScheduler(table))]:
            vals = [simulate(db, [app], poisson_trace(rate, 120, ["wifi_tx"],
                                                      seed=s), sched
                             ).avg_job_latency_us for s in range(3)]
            out[(name, rate)] = float(np.mean(vals))
    return out


def test_fig3_low_rate_schedulers_similar(fig3_data):
    """Paper: 'All schedulers perform similar at low job injection rates.'"""
    vals = [fig3_data[(n, 1.0)] for n in ["met", "etf", "ilp"]]
    assert max(vals) / min(vals) < 1.15


def test_fig3_high_rate_ordering(fig3_data):
    """Paper: at high rates ETF < ILP < MET in average job execution time."""
    assert fig3_data[("etf", 60.0)] < fig3_data[("ilp", 60.0)]
    assert fig3_data[("ilp", 60.0)] < fig3_data[("met", 60.0)]


def test_fig3_met_degrades_with_rate(fig3_data):
    assert fig3_data[("met", 60.0)] > 2.0 * fig3_data[("met", 1.0)]


def test_fig3_etf_stays_flat(fig3_data):
    assert fig3_data[("etf", 60.0)] < 1.25 * fig3_data[("etf", 1.0)]


# ---------------------------------------------------------------- schedulers

def test_registry_and_plugin_interface():
    assert {"met", "etf", "table"} <= set(available_schedulers())
    from repro.core.schedulers import Scheduler, register_scheduler

    @register_scheduler("_test_rr")
    class RoundRobin(Scheduler):
        def __init__(self):
            self.i = 0

        def pick_pe(self, db, ctx):
            name = ctx.app.tasks[ctx.task_id].name
            for k in range(db.num_pes):
                j = (self.i + k) % db.num_pes
                if db.supports(name, db.pes[j]):
                    self.i = j + 1
                    return j
            raise AssertionError

    db = make_soc_table2()
    app = wifi_tx()
    res = simulate(db, [app], deterministic_trace(1000.0, 5, ["wifi_tx"]),
                   get_scheduler("_test_rr"))
    assert len(res.records) == 5 * app.num_tasks


def test_optimal_table_beats_or_ties_everyone_single_job():
    """The ILP table is optimal for ONE job instance (paper §3)."""
    db = make_soc_table2()
    app = wifi_tx()
    table = solve_optimal_table(db, app)
    trace = deterministic_trace(1e6, 1, ["wifi_tx"])   # one isolated job
    opt = simulate(db, [app], trace, TableScheduler(table)).avg_job_latency_us
    for name in ["met", "etf"]:
        other = simulate(db, [app], trace, get_scheduler(name)).avg_job_latency_us
        assert opt <= other + 1e-3


def test_met_ignores_load_concentrates():
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(50.0, 60, ["wifi_tx"], seed=0)
    res = simulate(db, [app], trace, get_scheduler("met"))
    used = {r.pe_id for r in res.records}
    # canonical MET uses exactly one PE instance per distinct best type
    assert len(used) == 3   # SCR-0, A15-0, FFT-0


# ---------------------------------------------------------------- invariants

def _exec_us(db, app, rec):
    pe = db.pes[rec.pe_id]
    return db.profiles[app.tasks[rec.task_id].name][pe.pe_type]


@pytest.mark.parametrize("sched", ["met", "etf"])
def test_schedule_invariants(sched):
    db = make_soc_table2(with_viterbi=True)
    names = list(sorted(["wifi_tx", "wifi_rx", "range_detection",
                         "pulse_doppler", "single_carrier"]))
    apps = [get_application(n) for n in names]
    trace = poisson_trace(10.0, 60, names, seed=3)
    res = simulate(db, apps, trace, get_scheduler(sched))

    by_pe = {}
    for r in res.records:
        app = apps[int(trace.app_index[r.job_id])]
        assert r.start_us >= r.ready_us - 1e-3          # no time travel
        assert r.finish_us == pytest.approx(
            r.start_us + _exec_us(db, app, r), rel=1e-5)
        assert r.start_us >= trace.arrival_us[r.job_id] - 1e-3
        by_pe.setdefault(r.pe_id, []).append((r.start_us, r.finish_us))
        # dependencies respected (with comm >= 0)
        for p in app.tasks[r.task_id].predecessors:
            pr = next(x for x in res.records
                      if x.job_id == r.job_id and x.task_id == p)
            assert r.start_us >= pr.finish_us - 1e-3

    for pe_id, iv in by_pe.items():                      # PEs are sequential
        iv.sort()
        for (s0, f0), (s1, f1) in zip(iv, iv[1:]):
            assert s1 >= f0 - 1e-3
