"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps + hypothesis property tests per kernel, as required:
every kernel is asserted allclose against ``ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------- flash attn

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,KV,Dh", [
    (1, 128, 128, 4, 4, 32),
    (2, 256, 256, 4, 2, 64),      # GQA
    (1, 128, 128, 4, 1, 64),      # MQA
])
@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0), (96, 50.0)])
def test_flash_attention_matches_ref(dtype, B, Sq, Sk, H, KV, Dh, window,
                                     softcap):
    q = rnd(0, (B, Sq, H, Dh), dtype)
    k = rnd(1, (B, Sk, KV, Dh), dtype)
    v = rnd(2, (B, Sk, KV, Dh), dtype)
    scale = Dh ** -0.5
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, scale=scale,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   softcap=softcap, scale=scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64]),
       seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_flash_attention_block_size_invariance(bq, bk, seed):
    """Property: output must not depend on the BlockSpec tiling."""
    q = rnd(seed, (1, 128, 2, 2, 32)[:1] + (128, 2, 32))
    k = rnd(seed + 1, (1, 128, 2, 32))
    v = rnd(seed + 2, (1, 128, 2, 32))
    base = ops.flash_attention(q, k, v, scale=0.17, block_q=128, block_k=128)
    out = ops.flash_attention(q, k, v, scale=0.17, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- flash decode

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,H,KV,Dh,pos", [
    (2, 256, 4, 4, 32, 77),
    (1, 512, 8, 2, 64, 300),
    (2, 256, 4, 1, 128, 255),
])
def test_decode_attention_matches_ref(dtype, B, L, H, KV, Dh, pos):
    q = rnd(0, (B, 1, H, Dh), dtype)
    k = rnd(1, (B, L, KV, Dh), dtype)
    v = rnd(2, (B, L, KV, Dh), dtype)
    valid = jnp.arange(L) <= pos
    out = ops.decode_attention(q, k, v, valid, scale=Dh ** -0.5, block_k=128)
    want = ref.decode_attention_ref(q, k, v, valid, scale=Dh ** -0.5)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@given(pos=st.integers(0, 255), softcap=st.sampled_from([None, 20.0]))
@settings(max_examples=10, deadline=None)
def test_decode_attention_ring_mask_property(pos, softcap):
    """Property: arbitrary valid masks (ring buffers) stay allclose to ref."""
    B, L, H, KV, Dh = 1, 256, 2, 1, 32
    q, k, v = (rnd(i, s) for i, s in
               enumerate([(B, 1, H, Dh), (B, L, KV, Dh), (B, L, KV, Dh)]))
    window = 128
    slot_pos = pos - jnp.mod(jnp.mod(pos, L) - jnp.arange(L), L)
    valid = (slot_pos >= 0) & (slot_pos > pos - window)
    out = ops.decode_attention(q, k, v, valid, scale=0.2, softcap=softcap,
                               block_k=64)
    want = ref.decode_attention_ref(q, k, v, valid, scale=0.2, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- SSD scan

@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 256, 4, 32, 64, 64),
    (1, 64, 24, 64, 128, 16),     # mamba2-130m head geometry
])
def test_ssd_kernel_matches_naive_recurrence(B, L, H, P, N, chunk):
    x = rnd(0, (B, L, H, P), scale=0.5)
    dt = jax.nn.softplus(rnd(1, (B, L, H)))
    A = -jnp.exp(rnd(2, (H,), scale=0.3))
    Bm = rnd(3, (B, L, N), scale=0.3)
    Cm = rnd(4, (B, L, N), scale=0.3)

    nc = L // chunk
    dA = (dt * A).reshape(B, nc, chunk, H)
    cs = jnp.cumsum(dA, axis=2)
    y, hlast = ops.ssd_scan(x.reshape(B, nc, chunk, H, P),
                            dt.reshape(B, nc, chunk, H), dA, cs,
                            Bm.reshape(B, nc, chunk, N),
                            Cm.reshape(B, nc, chunk, N))
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)


@given(chunk=st.sampled_from([16, 32, 64]), seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_ssd_chunk_size_invariance(chunk, seed):
    """Property: chunking must not change the SSD result."""
    B, L, H, P, N = 1, 128, 2, 16, 16
    x = rnd(seed, (B, L, H, P), scale=0.5)
    dt = jax.nn.softplus(rnd(seed + 1, (B, L, H)))
    A = -jnp.exp(rnd(seed + 2, (H,), scale=0.3))
    Bm = rnd(seed + 3, (B, L, N), scale=0.3)
    Cm = rnd(seed + 4, (B, L, N), scale=0.3)
    nc = L // chunk
    dA = (dt * A).reshape(B, nc, chunk, H)
    cs = jnp.cumsum(dA, axis=2)
    y, _ = ops.ssd_scan(x.reshape(B, nc, chunk, H, P),
                        dt.reshape(B, nc, chunk, H), dA, cs,
                        Bm.reshape(B, nc, chunk, N),
                        Cm.reshape(B, nc, chunk, N))
    y_ref, _ = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- RG-LRU

@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 128, 64, 32, 64),
    (1, 256, 128, 128, 64),
    (3, 64, 256, 64, 128),
])
def test_rg_lru_matches_ref(B, S, W, bs, bw):
    a = jax.nn.sigmoid(rnd(0, (B, S, W)))          # decay in (0,1)
    x = rnd(1, (B, S, W), scale=0.5)
    out = ops.rg_lru(a, x, block_w=bw, block_s=bs)
    want = ref.rg_lru_ref(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rg_lru_with_initial_state():
    B, S, W = 1, 64, 32
    a = jax.nn.sigmoid(rnd(3, (B, S, W)))
    x = rnd(4, (B, S, W))
    h0 = rnd(5, (B, W))
    out = ops.rg_lru(a, x, h0)
    # fold h0 manually into the reference
    x2 = x.at[:, 0].add(a[:, 0] * h0)
    want = ref.rg_lru_ref(a, x2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------- kernels inside the model

@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_model_forward_pallas_path_matches_einsum(arch):
    """cfg.attn_impl='pallas' must reproduce the einsum forward."""
    from repro.configs import get_config, reduced
    from repro.models import build_model

    cfg = reduced(get_config(arch)).replace(window_size=64)
    B, S = 2, 128
    rng = jax.random.PRNGKey(0)
    model = build_model(cfg)
    params = model.init_params(rng)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    base = model.forward_logits(params, batch)
    model_k = build_model(cfg.replace(attn_impl="pallas"))
    out = model_k.forward_logits(params, batch)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(base, np.float32),
                               rtol=3e-2, atol=3e-2)
