"""Launch layer: specs, sharding rules, collective parsing, roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHITECTURES, SHAPES, cell_is_runnable, get_config, \
    get_shape
from repro.launch.dryrun import collective_bytes
from repro.launch.roofline import model_flops, _matmul_params
from repro.launch.specs import batch_specs
from repro.sharding import logical_to_pspec, rules_multi_pod, \
    rules_single_pod, use_mesh


HLO_SAMPLE = """
  %all-gather.15 = f32[1,128]{0,1} all-gather(%fusion.7), channel_id=19, replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.27 = bf16[4,256]{1,0} all-reduce(%wrapped), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0)
  %reduce-scatter.3 = f32[2,64]{1,0} reduce-scatter(%x), replica_groups=[32,8]<=[256], dimensions={1}
  %collective-permute.1 = bf16[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %all-gather-start.2 = (f32[1,8]{1,0}, f32[1,128]{1,0}) all-gather-start(%z), replica_groups=[16,16]<=[256]
  %all-gather-done.2 = f32[1,128]{1,0} all-gather-done(%all-gather-start.2)
"""


def test_collective_bytes_parser():
    res, wire, counts = collective_bytes(HLO_SAMPLE)
    assert counts == {"all-gather": 2, "all-reduce": 1, "reduce-scatter": 1,
                      "all-to-all": 0, "collective-permute": 1}
    assert res["all-gather"] == 128 * 4 + 128 * 4        # sync + start (max)
    assert res["all-reduce"] == 4 * 256 * 2
    assert wire["all-reduce"] == 2 * res["all-reduce"]   # RS+AG phases
    assert wire["reduce-scatter"] == 2 * 64 * 4 * 8      # result × group
    assert wire["collective-permute"] == 8 * 8 * 2


def test_rules_and_pspecs():
    r = rules_single_pod()
    assert r["batch"] == "data" and r["model"] == "model"
    rm = rules_multi_pod()
    assert rm["batch"] == ("pod", "data")


def test_cell_skips_match_design():
    runnable = {(a, s): cell_is_runnable(get_config(a), get_shape(s))[0]
                for a in ARCHITECTURES for s in SHAPES}
    # long_500k only for constant-state archs
    assert runnable[("mamba2-130m", "long_500k")]
    assert runnable[("recurrentgemma-2b", "long_500k")]
    for a in ["gemma2-2b", "dbrx-132b", "granite-3-8b", "paligemma-3b",
              "seamless-m4t-large-v2", "starcoder2-7b", "mistral-nemo-12b",
              "deepseek-moe-16b"]:
        assert not runnable[(a, "long_500k")], a
    # every other shape runs everywhere
    for a in ARCHITECTURES:
        for s in ["train_4k", "prefill_32k", "decode_32k"]:
            assert runnable[(a, s)], (a, s)


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_batch_specs_cover_all_inputs(arch):
    cfg = get_config(arch)
    for shape_name in ["train_4k", "decode_32k"]:
        shape = get_shape(shape_name)
        sds, ps = batch_specs(cfg, shape)
        assert set(sds) == set(ps)
        assert sds["tokens"].dtype == jnp.int32
        if shape.kind == "train":
            assert "labels" in sds
            if cfg.frontend == "vision":
                assert sds["patch_embeds"].shape[1] == cfg.num_prefix_tokens
                assert (sds["tokens"].shape[1]
                        == shape.seq_len - cfg.num_prefix_tokens)
            elif cfg.frontend == "audio":
                assert sds["frames"].shape == (shape.global_batch,
                                               shape.seq_len, cfg.d_model)
        else:
            assert sds["tokens"].shape == (shape.global_batch, 1)


def test_model_flops_sane():
    # granite-8B train: 6·N·D dominates; sanity vs parameter count
    f = model_flops("granite-3-8b", "train_4k")
    tokens = 256 * 4096
    n_active = sum(_matmul_params(get_config("granite-3-8b")).values())
    assert 7e9 < n_active < 9e9
    assert f > 6 * n_active * tokens            # attention adds on top
    assert f < 6 * n_active * tokens * 1.6
    # moe: active params well below total
    n_moe = sum(_matmul_params(get_config("deepseek-moe-16b")).values())
    assert n_moe < 5e9                           # 16B total, ~3B active
    # decode flops are ~2·N·B
    fd = model_flops("granite-3-8b", "decode_32k")
    assert fd < f / 1000


def test_long500k_shapes_divisible_for_kv_seq_sharding():
    for arch in ["mamba2-130m", "recurrentgemma-2b"]:
        cfg = get_config(arch)
        s = get_shape("long_500k")
        assert s.seq_len % 16 == 0
        if cfg.window_size:
            assert cfg.window_size % 16 == 0
