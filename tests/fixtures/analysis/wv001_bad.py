"""WV001 fixture — a waiver with no justification (strict-mode finding)."""
import jax
import numpy as np


@jax.jit
def f(x):
    return np.mean(x)  # lint: waive JX002
