"""JX003 fixtures — impure jitted bodies (all bad)."""
import random
import time

import jax

CALLS = []


@jax.jit
def noisy(x):
    print("tracing", x)                # line 12: JX003 print
    return x


@jax.jit
def clocked(x):
    t0 = time.perf_counter()           # line 18: JX003 wall clock
    return x + t0


@jax.jit
def seeded(x):
    return x + random.random()         # line 24: JX003 host RNG


@jax.jit
def appends(x):
    CALLS.append(1)                    # line 29: JX003 global mutation
    return x


class Model:
    @jax.jit
    def step(self, x):
        self.count = 1                 # line 36: JX003 self mutation
        return x
