"""JX001 fixtures — tracer leaks inside jit-reachable code (all bad)."""
import jax
import jax.numpy as jnp


@jax.jit
def leak_item(x):
    return x.item()                    # line 8: JX001 .item()


@jax.jit
def leak_cast(x):
    return float(x)                    # line 13: JX001 float()


@jax.jit
def leak_branch(x):
    if jnp.max(x) > 0:                 # line 18: JX001 if on array expr
        return x
    return -x


def body(carry, x):
    while jnp.sum(carry) < 10:         # line 24: JX001 while on array expr
        carry = carry + x
    return carry, x


def scan_it(xs):
    return jax.lax.scan(body, jnp.zeros(()), xs)
