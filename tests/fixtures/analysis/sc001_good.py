"""SC001 fixtures — stable loop carries (all good)."""
import functools

import jax
import jax.numpy as jnp


def dict_body(state, _):
    out = {"t_us": state["t_us"] + 1.0, "e_j": state["e_j"]}
    return out, None


def scan_dict(xs):
    return jax.lax.scan(dict_body, {"t_us": 0.0, "e_j": 0.0}, xs)


def floor_body(carry):
    i, acc = carry
    return (i // 2, acc + 1)                 # floor division keeps int


def halve(x):
    return jax.lax.while_loop(lambda c: c[0] > 0, floor_body, (8, x))


def float_div_body(carry, x):
    t, e = carry
    return (t / 2.0, e), x                   # float init: division is fine


def scan_float(xs):
    return jax.lax.scan(float_div_body, (jnp.zeros(()), jnp.zeros(())), xs)


def sym_body(idx, carry):
    a, b = carry
    if idx > 3:
        return (a.astype(jnp.float32), b)
    return (a.astype(jnp.float32), b)        # astype on every path: stable


def fori_sym(a0, b0):
    return jax.lax.fori_loop(0, 10, sym_body, (a0, b0))


def windowed(tables, carry):
    lo, hi, t, e = carry
    return (lo + 1, hi, t, e)


def advance(tables, x):
    return jax.lax.while_loop(
        lambda c: c[0] < c[1],
        functools.partial(windowed, tables),  # bound arg shifts the carry
        (0, 4, x, x))


def _step(state):
    return {"t_us": state["t_us"] + 1.0}


def opaque_body(state, _):
    return _step(state), None                # opaque carry: never guessed at


def scan_opaque(xs):
    return jax.lax.scan(opaque_body, {"t_us": 0.0}, xs)
