"""SH001 fixtures — sharding contracts honored (all good)."""
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


LANE_SPEC = P("lanes")                       # leading lane axis


def make_lane_mesh():
    return Mesh(jax.devices(), ("lanes",))   # host-side mesh construction


def place(tree, mesh):
    sharding = NamedSharding(mesh, P("lanes"))
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), tree)


@jax.jit
def grid(x):
    return x * 2
