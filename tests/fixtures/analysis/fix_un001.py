"""--fix fixture — UN001 violations the rename engine must repair."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    energy: float                            # -> energy_j
    power: float                             # -> power_w
    latency: float                           # -> latency_us
    num_jobs: int

    def to_dict(self):
        return {"energy": self.energy,
                "power": self.power,
                "latency": self.latency,
                "num_jobs": self.num_jobs}


def summarize(scale):
    rep = EnergyReport(energy=1.0, power=2.0, latency=3.0, num_jobs=4)
    return rep.energy * scale + rep.power + rep.latency
