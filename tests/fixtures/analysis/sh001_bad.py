"""SH001 fixtures — sharding contract violations (all bad)."""
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


BAD_SPEC = P("model", "lanes")               # line 6: SH001 lane axis trailing


def tucked(mesh):
    return NamedSharding(mesh, P(None, "lanes"))   # line 10: SH001


@jax.jit
def place_inside(x):
    y = jax.device_put(x)                    # line 15: SH001 device_put in jit
    return y * 2


@jax.jit
def mesh_inside(x):
    mesh = Mesh(jax.devices(), ("lanes",))   # line 21: SH001 mesh under trace
    del mesh
    return x
