"""UN001 fixtures — unit-less numeric fields on a result struct (bad)."""
import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class EvalResult:
    latency: np.ndarray                # line 9: UN001 no unit suffix
    energy_j: np.ndarray
    temperature: float                 # line 11: UN001 no unit suffix
    num_designs: int                   # int: exempt

    def to_dict(self):
        return {"latency": 0.0,        # line 15: UN001 payload key
                "energy_j": 0.0}
