"""JX003 fixtures — effects on the host side of the jit boundary (clean)."""
import time

import jax
import jax.numpy as jnp

COMPILES = []


@jax.jit
def pure_step(x):
    jax.debug.print("x={x}", x=x)      # the sanctioned per-call print
    return jnp.tanh(x)


@jax.jit
def counted(x):
    COMPILES.append(1)  # lint: waive JX003 -- fixture: compile counter idiom
    return x


def timed_host_call(x):
    t0 = time.perf_counter()           # host code: not jit-reachable
    y = pure_step(x)
    return y, time.perf_counter() - t0
