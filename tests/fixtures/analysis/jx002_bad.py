"""JX002 fixtures — host numpy on traced data inside jit (bad + waiver)."""
import jax
import numpy as np


@jax.jit
def host_mean(x):
    return np.mean(x)                  # line 8: JX002 np on traced arg


@jax.jit
def waived_mean(x):
    return np.mean(x)  # lint: waive JX002 -- fixture: demonstrates waiver
