"""DN001 fixtures — donation used correctly (all good)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnames=("buf",))
def consume(buf, scale):
    return buf * scale


def stream(chunks, scale):
    total = jnp.zeros(())
    for piece in chunks:
        out = consume(jnp.asarray(piece), scale)  # fresh buffer per call
        total = total + out.sum()
    return total


def refresh(buf, scale):
    out = consume(buf, scale)
    buf = jnp.zeros_like(out)                # rebind refreshes the buffer
    return out + buf


def untouched(buf, scale):
    pre = buf.sum()                          # read before the donation
    return consume(buf, scale) + pre


def kept(buf, scale):
    return consume(buf, scale=scale)         # scale is not donated
