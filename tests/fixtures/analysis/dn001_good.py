"""DN001 fixtures — donation used correctly (all good)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnames=("buf",))
def consume(buf, scale):
    return buf * scale


def stream(chunks, scale):
    total = jnp.zeros(())
    for piece in chunks:
        out = consume(jnp.asarray(piece), scale)  # fresh buffer per call
        total = total + out.sum()
    return total


def refresh(buf, scale):
    out = consume(buf, scale)
    buf = jnp.zeros_like(out)                # rebind refreshes the buffer
    return out + buf


def untouched(buf, scale):
    pre = buf.sum()                          # read before the donation
    return consume(buf, scale) + pre


def kept(buf, scale):
    return consume(buf, scale=scale)         # scale is not donated


def multiline(buf, very_long_scale_name):
    out = consume(
        buf,                                 # the donating call's own args
        very_long_scale_name)                # span lines: not a use-after
    return out


def exclusive(buf, scale, fancy):
    if fancy:
        out = consume(
            buf, scale)                      # donates in the if-branch...
    else:
        out = buf * scale                    # ...so the else never follows it
    return out


def early_return(buf, scale, fancy):
    if fancy:
        return consume(buf, scale)           # returns: nothing follows it
    return buf * scale
