"""DN001 fixtures — reads after donation (all bad)."""
import functools

import jax


@functools.partial(jax.jit, donate_argnames=("buf",))
def consume(buf, scale):
    return buf * scale


def stream(buf, scale):
    out = consume(buf, scale)
    total = buf.sum()                        # line 14: DN001 read after donate
    return out, total


def stream_kw(b, s):
    out = consume(buf=b, scale=s)
    return out + b                           # line 20: DN001 read after donate


def _accumulate(acc, x):
    return acc + x


step = jax.jit(_accumulate, donate_argnums=(0,))


def run(acc, xs):
    acc2 = step(acc, xs)
    return acc2 + acc                        # line 32: DN001 read after donate


def _grid(tables, gov):
    return tables + gov


_chunk = functools.partial(jax.jit, donate_argnames=("tables",))(_grid)


def launch(tables, gov):
    r = _chunk(tables, gov)
    return r + tables                        # line 44: DN001 read after donate


def after_branch(buf, scale, fancy):
    if fancy:
        out = consume(buf, scale)
    else:
        out = buf * scale
    return out + buf.sum()                   # line 52: DN001 read after the if
