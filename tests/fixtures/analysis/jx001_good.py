"""JX001 fixtures — tracer-safe idioms that must stay clean."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("policy",))
def static_branch(x, policy: str):
    if policy == "etf":                # static argname: compile-time branch
        return jnp.sort(x)
    return x


@jax.jit
def shape_math(x):
    n = int(x.shape[0] * 2)            # shape is static metadata
    return jnp.pad(x, (0, n - len(x)))


@jax.jit
def constant_fold(x):
    scale = float(np.pi / 2)           # host float on constants, no tracer
    return x * scale


def host_driver(xs):
    out = jax.jit(shape_math)(xs)
    return float(np.mean(np.asarray(out)))   # host side: not jit-reachable
