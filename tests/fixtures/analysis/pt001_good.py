"""PT001 fixtures — well-formed pytree registrations (clean)."""
import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class Tables:
    exec_us: np.ndarray
    power_w: np.ndarray
    num_pes: int


jax.tree_util.register_dataclass(Tables, data_fields=["exec_us", "power_w"],
                                 meta_fields=["num_pes"])


@dataclasses.dataclass(frozen=True)
class SpecA:
    rate: float
    seed: int


@dataclasses.dataclass(frozen=True)
class SpecB:
    cap: float


# loop registration with a computed split: frozen check still applies
for _cls in (SpecA, SpecB):
    jax.tree_util.register_dataclass(
        _cls, data_fields=[],
        meta_fields=[f.name for f in dataclasses.fields(_cls)])
