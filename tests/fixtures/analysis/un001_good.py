"""UN001 fixtures — suffixed/allowlisted fields (clean)."""
import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    total_energy_j: float
    avg_power_w: float
    makespan_us: float
    peak_temp_c: float
    freq_ghz: float
    utilization: np.ndarray            # allowlisted (dimensionless)
    freq_idx: np.ndarray               # allowlisted (*_idx)
    num_pes: int                       # int: exempt
    telemetry: Optional[np.ndarray] = None   # allowlisted container

    def to_dict(self):
        return dict(total_energy_j=self.total_energy_j,
                    avg_power_w=self.avg_power_w,
                    makespan_us=self.makespan_us)


@dataclasses.dataclass
class NotAudited:
    latency: float                     # class not in unit-structs: ignored
