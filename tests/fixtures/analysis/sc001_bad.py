"""SC001 fixtures — unstable loop carries (all bad)."""
import jax
import jax.numpy as jnp


def triple_body(carry, x):
    return carry, x, x                      # line 7: SC001 not a (carry, ys) pair


def scan_triple(xs):
    return jax.lax.scan(triple_body, jnp.zeros(()), xs)


def grow_body(carry, x):
    a, b = carry
    return (a, b, x), None                  # line 16: SC001 arity 2 -> 3


def scan_grow(xs):
    return jax.lax.scan(grow_body, (jnp.zeros(()), jnp.zeros(())), xs)


def swap_body(carry, x):
    a, b = carry
    return (b, a), x                        # line 25: SC001 reordered carry


def scan_swap(xs):
    return jax.lax.scan(swap_body, (jnp.zeros(()), jnp.zeros(())), xs)


def div_body(carry):
    i, acc = carry
    return (i / 2, acc + 1)                 # line 34: SC001 true division on int carry


def count_down(x):
    return jax.lax.while_loop(lambda c: c[0] > 0, div_body, (8, x))


def mean_body(carry, x):
    acc, n = carry
    return (jnp.mean(acc), n), x            # line 43: SC001 jnp.mean on int carry


def scan_mean(xs):
    return jax.lax.scan(mean_body,
                        (jnp.zeros((4,), dtype=jnp.int32), jnp.zeros(())),
                        xs)


def cast_body(carry):
    v, y = carry
    return (v.astype(jnp.int32), y)         # line 54: SC001 astype int vs float init


def cast_loop(x):
    return jax.lax.while_loop(lambda c: c[1] > 0, cast_body,
                              (jnp.float32(0.0), x))


def branchy_body(idx, carry):
    a, b = carry
    if idx > 3:
        return (a.astype(jnp.float32), b)   # line 65: SC001 astype on 1 of 2 paths
    return (a, b)


def fori_branchy(a0, b0):
    return jax.lax.fori_loop(0, 10, branchy_body, (a0, b0))
