"""PT001 fixtures — broken pytree registrations (all bad)."""
import dataclasses

import jax
import numpy as np


@dataclasses.dataclass                         # not frozen
class Mutable:
    x: np.ndarray


jax.tree_util.register_dataclass(Mutable, data_fields=["x"],
                                 meta_fields=[])          # line 13: PT001


@dataclasses.dataclass(frozen=True)
class Dropped:
    x: np.ndarray
    y: np.ndarray


jax.tree_util.register_dataclass(Dropped, data_fields=["x"],
                                 meta_fields=[])          # line 23: PT001 y


@dataclasses.dataclass(frozen=True)
class ArrayMeta:
    x: np.ndarray
    lut: np.ndarray


jax.tree_util.register_dataclass(ArrayMeta, data_fields=["x"],
                                 meta_fields=["lut"])     # line 33: PT001
