"""PE fail-stop injection: the paper-side fault-tolerance story.

A PE dies mid-workload; in-flight/queued tasks (and committed descendants)
roll back and reschedule on survivors — all jobs still complete, with
degraded latency.  Mirrors the pod half's preemption/restart semantics."""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import (get_scheduler, make_soc_table2, poisson_trace,
                        wifi_tx)
from repro.core.simkernel_ref import simulate


def _all_jobs_complete(res, trace, app):
    per_job = {}
    for r in res.records:
        per_job.setdefault(r.job_id, set()).add(r.task_id)
    return all(per_job.get(j, set()) == set(range(app.num_tasks))
               for j in range(trace.num_jobs))


@pytest.mark.parametrize("sched", ["met", "etf"])
def test_single_pe_failure_all_jobs_complete(sched):
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(20.0, 60, ["wifi_tx"], seed=0)
    base = simulate(db, [app], trace, get_scheduler(sched))
    res = simulate(db, [app], trace, get_scheduler(sched),
                   failures=[(0, 300.0)])           # A15-0 dies at t=300us
    assert _all_jobs_complete(res, trace, app)
    assert not any(r.pe_id == 0 and r.finish_us > 300.0 for r in res.records)
    assert res.avg_job_latency_us >= base.avg_job_latency_us - 1e-3


def test_accelerator_failure_falls_back_to_cpu():
    """All FFT accelerators die -> inverse_fft reschedules onto CPUs."""
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(5.0, 30, ["wifi_tx"], seed=1)
    failures = [(pe.pe_id, 100.0) for pe in db.pes_of_type("FFT_ACC")]
    res = simulate(db, [app], trace, get_scheduler("etf"), failures=failures)
    assert _all_jobs_complete(res, trace, app)
    ifft_id = app.task_names.index("inverse_fft")
    late_ifft = [r for r in res.records
                 if r.task_id == ifft_id and r.start_us > 150.0]
    assert late_ifft and all(db.pes[r.pe_id].is_cpu for r in late_ifft)
    # CPU iFFT is 118us vs 16us on the accelerator: latency must degrade
    base = simulate(db, [app], trace, get_scheduler("etf"))
    assert res.avg_job_latency_us > base.avg_job_latency_us * 1.5


def test_failure_invariants_hold_after_rollback():
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(30.0, 50, ["wifi_tx"], seed=2)
    res = simulate(db, [app], trace, get_scheduler("etf"),
                   failures=[(0, 200.0), (8, 400.0)])   # A15-0 and SCR-0
    assert _all_jobs_complete(res, trace, app)
    by_pe = {}
    for r in res.records:
        by_pe.setdefault(r.pe_id, []).append((r.start_us, r.finish_us))
        for p in app.tasks[r.task_id].predecessors:
            pr = next(x for x in res.records
                      if x.job_id == r.job_id and x.task_id == p)
            assert r.start_us >= pr.finish_us - 1e-3    # deps still respected
    for iv in by_pe.values():                           # PEs still sequential
        iv.sort()
        for (s0, f0), (s1, f1) in zip(iv, iv[1:]):
            assert s1 >= f0 - 1e-3


@given(fail_t=st.floats(50.0, 2000.0), pe=st.integers(0, 13),
       seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_property_any_single_failure_completes(fail_t, pe, seed):
    """Property: any single PE failure at any time still completes the
    workload (the Table-2 SoC has >=2 PEs of every capability)."""
    db = make_soc_table2()
    app = wifi_tx()
    trace = poisson_trace(15.0, 25, ["wifi_tx"], seed=seed)
    res = simulate(db, [app], trace, get_scheduler("etf"),
                   failures=[(pe, fail_t)])
    assert _all_jobs_complete(res, trace, app)
    assert np.isfinite(res.makespan_us)
