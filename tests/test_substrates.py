"""Substrate tests: data pipeline, optimizer, compression, checkpointing."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_stub import given, settings, st

from repro.data import SyntheticLMPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress_int8,
                         decompress_int8, ef_compress_grads, ef_init)
from repro.checkpoint import CheckpointManager


# ---------------------------------------------------------------- data

def test_pipeline_deterministic_addressing():
    p1 = SyntheticLMPipeline(1000, 8, 64, seed=3)
    p2 = SyntheticLMPipeline(1000, 8, 64, seed=3)
    for step in [0, 5, 17]:
        np.testing.assert_array_equal(p1.batch_at(step)["tokens"],
                                      p2.batch_at(step)["tokens"])


def test_pipeline_restart_no_drift():
    p = SyntheticLMPipeline(1000, 4, 32, seed=0)
    seen = [p.next_batch()["tokens"] for _ in range(6)]
    state = p.state_dict()
    # restart from a checkpointed state at step 3
    p2 = SyntheticLMPipeline(1000, 4, 32, seed=999)
    p2.load_state_dict({"seed": 0, "step": 3})
    np.testing.assert_array_equal(p2.next_batch()["tokens"], seen[3])
    np.testing.assert_array_equal(p2.next_batch()["tokens"], seen[4])


def test_pipeline_host_sharding_partitions_global_batch():
    g = SyntheticLMPipeline(500, 8, 16, seed=1).batch_at(7)["tokens"]
    parts = [SyntheticLMPipeline(500, 8, 16, seed=1, host_index=i,
                                 host_count=4).batch_at(7)["tokens"]
             for i in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    # host shards are mutually distinct slices of the same distribution
    assert len({p.tobytes() for p in parts}) == 4


def test_pipeline_labels_shifted():
    b = SyntheticLMPipeline(100, 2, 16, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------- optimizer

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0], jnp.float32)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params=params)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    new, opt, gnorm = adamw_update(cfg, g, opt, params=params)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)
    assert np.all(np.abs(np.asarray(new["w"])) < 2.0)   # clipped step


@given(seed=st.integers(0, 50), scale=st.sampled_from([1e-4, 1.0, 1e4]))
@settings(max_examples=20, deadline=None)
def test_int8_compression_bounded_error(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = compress_int8(x)
    rt = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    # error bounded by half a quantisation bucket
    np.testing.assert_allclose(np.asarray(rt), np.asarray(x),
                               atol=float(s) * 0.51 + 1e-12)


def test_error_feedback_preserves_signal_over_steps():
    """EF: the accumulated transmitted signal tracks the true gradient sum."""
    rng = np.random.default_rng(0)
    true = [rng.normal(size=64).astype(np.float32) * 1e-3 for _ in range(50)]
    err = ef_init({"g": jnp.zeros(64)})
    sent = np.zeros(64, dtype=np.float64)
    for g in true:
        rt, err = ef_compress_grads({"g": jnp.asarray(g)}, err)
        sent += np.asarray(rt["g"], np.float64)
    total = np.sum(true, axis=0)
    resid = np.asarray(err["g"])
    np.testing.assert_allclose(sent + resid, total, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- checkpoint

def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": {"w": jax.random.normal(k, (4, 8), jnp.float32),
                  "b": jax.random.normal(k, (8,), jnp.float32).astype(jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = make_tree()
    mgr.save(10, tree, meta={"data": {"seed": 0, "step": 10}})
    got, meta = mgr.restore()
    assert meta["step"] == 10 and meta["data"]["step"] == 10
    np.testing.assert_array_equal(np.asarray(got["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))
    assert got["a"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["a"]["b"], np.float32),
                                  np.asarray(tree["a"]["b"], np.float32))


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, make_tree(s))
    assert mgr.latest_step() == 4
    assert mgr.steps() == [3, 4]                 # older ones collected
    got, _ = mgr.restore(step=3)
    assert got is not None


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, make_tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_crash_mid_write_keeps_previous(tmp_path):
    """A partially-written checkpoint must never become the restore point."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, make_tree(1))
    # simulate a crash: a stale tmp dir + step dir without manifest bump
    d = tmp_path / "step_000000099"
    d.mkdir()
    (d / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1                # manifest still points at 1
    got, meta = mgr.restore()
    assert meta["step"] == 1


def test_checkpoint_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = mgr.restore(shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
