"""Pipeline parallelism: GPipe over the pod axis must match the scan stack
numerically (same params, same batch) — run in a subprocess so the forced
8-device CPU topology doesn't leak into other tests."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.sharding import use_mesh
    from repro.launch.mesh import rules_for

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = reduced(get_config("granite-3-8b"))          # 2 repeats % 2 stages
    assert cfg.num_layers % 2 == 0
    B, S = 4, 32
    rules = rules_for(mesh, batch_size=B, kind="train_pp")

    with use_mesh(mesh, rules):
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

        base = jax.jit(model.loss_fn)(params, batch)

        cfg_pp = cfg.replace(pipeline_stages=2, pipeline_microbatches=2)
        model_pp = build_model(cfg_pp)
        # stage-shard the stacked layer params over `pod`
        def shard_stack(path, leaf):
            names = [getattr(k, "key", None) for k in path]
            if "stack" in names:
                return jax.device_put(leaf, NamedSharding(
                    mesh, P(*("pod",) + (None,) * (leaf.ndim - 1))))
            return leaf
        params_pp = jax.tree_util.tree_map_with_path(shard_stack, params)
        pp = jax.jit(model_pp.loss_fn)(params_pp, batch)

        # gradients flow through ppermute (backward pipeline)
        g = jax.jit(jax.grad(model_pp.loss_fn))(params_pp, batch)
        gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                 for x in jax.tree.leaves(g))

    err = abs(float(pp) - float(base))
    print(f"RESULT base={float(base):.6f} pp={float(pp):.6f} err={err:.2e} "
          f"gn={gn:.3e}")
    assert err < 5e-3, (float(base), float(pp))
    assert np.isfinite(gn) and gn > 0
""")


def test_gpipe_matches_scan_stack():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT" in out.stdout, out.stdout
