"""Cross-validation: the vectorised JAX kernel vs the event-heap reference.

This carries the paper's hardware-validation duty (we have no Zynq board):
the two independent implementations must agree — exactly on comm-free
integer-latency workloads, and to float tolerance in general.
"""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import (TableScheduler, build_tables, deterministic_trace,
                        get_application, get_scheduler, make_soc_table2,
                        poisson_trace, solve_optimal_table, wifi_tx)
# kernels imported directly: the repro.core re-exports are deprecation shims
from repro.core.simkernel_jax import simulate_batch, simulate_jax
from repro.core.simkernel_ref import simulate
from repro.core.applications import Application, Task
from repro.core.resources import ALL_PROFILES, CommModel, ResourceDB, make_soc


APPS5 = ["wifi_tx", "wifi_rx", "single_carrier", "range_detection",
         "pulse_doppler"]


def _run_both(db, apps, trace, policy, table=None):
    sched = (TableScheduler(table) if policy == "table"
             else get_scheduler(policy))
    ref = simulate(db, apps, trace, sched)
    tables = build_tables(db, apps, table=table)
    jx = simulate_jax(tables, policy, trace.arrival_us, trace.app_index)
    return ref, jx


@pytest.mark.parametrize("policy", ["met", "etf", "table"])
@pytest.mark.parametrize("rate", [2.0, 20.0, 60.0])
def test_kernels_agree_wifi_tx(policy, rate):
    db = make_soc_table2()
    app = wifi_tx()
    table = solve_optimal_table(db, app) if policy == "table" else None
    trace = poisson_trace(rate, 80, ["wifi_tx"], seed=int(rate))
    ref, jx = _run_both(db, [app], trace, policy, table)
    np.testing.assert_allclose(float(jx["avg_job_latency_us"]),
                               ref.avg_job_latency_us, rtol=1e-4)
    np.testing.assert_allclose(float(jx["makespan_us"]), ref.makespan_us,
                               rtol=1e-4)
    np.testing.assert_allclose(float(jx["energy_j"]),
                               ref.energy.total_energy_j, rtol=1e-3)


@pytest.mark.parametrize("policy", ["met", "etf"])
def test_kernels_agree_five_app_mix(policy):
    db = make_soc_table2(with_viterbi=True)
    apps = [get_application(n) for n in APPS5]
    trace = poisson_trace(15.0, 60, APPS5, seed=7)
    ref, jx = _run_both(db, apps, trace, policy)
    np.testing.assert_allclose(float(jx["avg_job_latency_us"]),
                               ref.avg_job_latency_us, rtol=1e-4)


def test_exact_schedule_equality_comm_free():
    """Integer latencies + zero comm => bit-exact schedules in float32."""
    db = make_soc_table2()
    db.comm = CommModel(startup_us=0.0, bw_bytes_per_us=1e30)
    app = wifi_tx()
    trace = deterministic_trace(25.0, 64, ["wifi_tx"])
    ref, jx = _run_both(db, [app], trace, "etf")
    fin = np.asarray(jx["finish"])
    onpe = np.asarray(jx["onpe"])
    for r in ref.records:
        assert fin[r.job_id, r.task_id] == np.float32(r.finish_us)
        assert onpe[r.job_id, r.task_id] == r.pe_id


def test_batched_vmap_matches_loop():
    db = make_soc_table2()
    app = wifi_tx()
    tables = build_tables(db, [app])
    traces = [poisson_trace(r, 40, ["wifi_tx"], seed=s)
              for r in (5.0, 30.0) for s in (0, 1)]
    arr = np.stack([t.arrival_us for t in traces])
    idx = np.stack([t.app_index for t in traces])
    batch = simulate_batch(tables, "etf", arr, idx)
    for k, t in enumerate(traces):
        single = simulate_jax(tables, "etf", t.arrival_us, t.app_index)
        np.testing.assert_allclose(float(batch["avg_job_latency_us"][k]),
                                   float(single["avg_job_latency_us"]),
                                   rtol=1e-6)


# ------------------------------------------------------------- property-based

_TASK_NAMES = sorted(ALL_PROFILES.keys())


@st.composite
def random_dag_app(draw):
    n = draw(st.integers(min_value=1, max_value=8))
    tasks = []
    for i in range(n):
        name = draw(st.sampled_from(_TASK_NAMES))
        if i == 0:
            preds = ()
        else:
            k = draw(st.integers(min_value=0, max_value=min(i, 3)))
            preds = tuple(sorted(draw(
                st.sets(st.integers(0, i - 1), min_size=k, max_size=k))))
        nbytes = float(draw(st.sampled_from([256, 1024, 4096])))
        tasks.append(Task(name, i, preds, nbytes))
    return Application("rand", tuple(tasks))


@given(app=random_dag_app(),
       rate=st.sampled_from([2.0, 20.0, 80.0]),
       seed=st.integers(0, 10),
       policy=st.sampled_from(["met", "etf"]))
@settings(max_examples=25, deadline=None)
def test_property_kernels_agree_on_random_dags(app, rate, seed, policy):
    db = make_soc_table2(with_viterbi=True)
    trace = poisson_trace(rate, 20, ["rand"], seed=seed)
    ref, jx = _run_both(db, [app], trace, policy)
    np.testing.assert_allclose(float(jx["avg_job_latency_us"]),
                               ref.avg_job_latency_us, rtol=2e-4)
    # invariant: makespan at least the (exec-only) critical path of one job
    cp = np.zeros(app.num_tasks)
    for t in app.tasks:
        best = min(v for v in ALL_PROFILES[t.name].values())
        cp[t.task_id] = best + max([cp[p] for p in t.predecessors], default=0.0)
    assert float(jx["makespan_us"]) >= cp.max() - 1e-3
