"""repro.dse — design-space exploration subsystem tests.

Covers: Pareto machinery on hand-checkable sets, design-space enumeration
determinism, padded-batch vs per-design kernel equivalence (the batching
correctness contract), and the JAX RC thermal model against the analytical
steady state / the numpy reference integrator.
"""
import numpy as np
import pytest

from repro.core import build_tables, poisson_trace, thermal, \
    wifi_tx, get_application
# kernels imported directly: the re-exports are deprecation shims
from repro.core.simkernel_jax import simulate_jax
from repro.dse.batch import simulate_design_batch
from repro.dse import (DesignPoint, DesignSpace, binned_power_trace,
                       build_design_batch, crowding_distance, evaluate,
                       non_dominated_sort, pareto_mask, pareto_search,
                       peak_temperature_grid,
                       stack_traces, successive_halving, transient_trace)
from repro.dse import thermal_jax

APPS = ["wifi_tx", "wifi_rx"]


def _apps():
    return [get_application(n) for n in APPS]


def _traces(n=2, jobs=12, rate=25.0, seed=0):
    return [poisson_trace(rate, jobs, APPS, seed=seed + i) for i in range(n)]


# ------------------------------------------------------------------ pareto

def test_pareto_mask_hand_checkable():
    # minimise both axes; (1,5) (2,2) (5,1) are the front, rest dominated
    costs = np.array([[1.0, 5.0], [2.0, 2.0], [5.0, 1.0],
                      [2.0, 5.0], [3.0, 3.0], [6.0, 6.0]])
    assert pareto_mask(costs).tolist() == [True, True, True,
                                           False, False, False]


def test_pareto_duplicates_both_survive():
    costs = np.array([[1.0, 1.0], [1.0, 1.0], [2.0, 2.0]])
    assert pareto_mask(costs).tolist() == [True, True, False]


def test_non_dominated_sort_ranks():
    costs = np.array([[1.0, 4.0], [4.0, 1.0],      # front 0
                      [2.0, 5.0], [5.0, 2.0],      # front 1
                      [6.0, 6.0]])                 # front 2
    assert non_dominated_sort(costs).tolist() == [0, 0, 1, 1, 2]


def test_crowding_distance_boundaries_inf():
    costs = np.array([[0.0, 4.0], [1.0, 2.0], [2.0, 1.0], [4.0, 0.0]])
    d = crowding_distance(costs)
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert np.all(np.isfinite(d[1:3])) and np.all(d[1:3] > 0)


# ------------------------------------------------------------- design space

def test_grid_deterministic_and_valid():
    space = DesignSpace(num_big=(0, 1), num_little=(0, 2), num_scr=(0, 1),
                        num_fft=(0, 1), num_vit=(0,),
                        big_freq_ghz=(2.0,), little_freq_ghz=(1.4,))
    g1, g2 = space.grid(), space.grid()
    assert g1 == g2
    assert all(p.is_valid() for p in g1)
    # 2*2*2*2 = 16 combos minus the 4 CPU-less (big=0, little=0) ones
    assert len(g1) == 12


def test_grid_budget_filter():
    space = DesignSpace()
    budget = 10.0
    pts = space.grid(budget_mm2=budget)
    assert pts and all(p.area_mm2 <= budget for p in pts)
    assert len(pts) < len(space.grid())


def test_sampling_deterministic_per_seed():
    space = DesignSpace()
    a = space.sample_lhs(24, seed=7)
    b = space.sample_lhs(24, seed=7)
    c = space.sample_lhs(24, seed=8)
    assert a == b and a != c
    assert len(a) == len(set(a)) == 24
    r1 = space.sample_random(16, seed=3)
    assert r1 == space.sample_random(16, seed=3)
    assert len(set(r1)) == 16 and all(space.contains(p) for p in r1)


def test_neighbors_stay_in_space():
    space = DesignSpace()
    p = space.sample_lhs(1, seed=0)[0]
    nbrs = space.neighbors(p)
    assert nbrs and all(space.contains(q) and q.is_valid() for q in nbrs)
    assert all(q != p for q in nbrs)


# ------------------------------------------------- batched kernel equivalence

@pytest.mark.parametrize("policy", ["met", "etf"])
def test_padded_batch_matches_per_design(policy):
    """The batching contract: stacking + vmap must reproduce per-design
    simulate_jax bit-for-bit (padding is inert, vmap lane == single call)."""
    points = [DesignPoint(4, 4, 2, 4, 0), DesignPoint(1, 2, 0, 1, 0),
              DesignPoint(0, 4, 1, 2, 1, big_freq_ghz=1.4),
              DesignPoint(2, 0, 2, 0, 0, cross_cluster_penalty=4.0)]
    apps = _apps()
    traces = _traces(3)
    batch = build_design_batch(points, apps)
    arrival, app_idx = stack_traces(traces)
    out = simulate_design_batch(batch, policy, arrival, app_idx)
    for d, p in enumerate(points):
        tables = build_tables(p.to_db(), apps, governor=p.governor())
        for s, tr in enumerate(traces):
            ref = simulate_jax(tables, policy, tr.arrival_us, tr.app_index)
            np.testing.assert_array_equal(
                np.asarray(out["avg_job_latency_us"])[d, s],
                np.asarray(ref["avg_job_latency_us"]))
            np.testing.assert_array_equal(
                np.asarray(out["makespan_us"])[d, s],
                np.asarray(ref["makespan_us"]))
            np.testing.assert_array_equal(
                np.asarray(out["energy_j"])[d, s],
                np.asarray(ref["energy_j"]))
            np.testing.assert_array_equal(
                np.asarray(out["busy_per_pe_us"])[d, s, :p.num_pes],
                np.asarray(ref["busy_per_pe_us"]))
            # padded PE slots never execute anything
            assert np.all(np.asarray(out["busy_per_pe_us"])[d, s,
                                                            p.num_pes:] == 0)


def test_build_tables_pad_validation():
    db = DesignPoint(2, 2, 1, 1, 0).to_db()
    with pytest.raises(ValueError):
        build_tables(db, [wifi_tx()], pad_pes=db.num_pes - 1)
    with pytest.raises(ValueError):
        build_tables(db, [wifi_tx()], pad_tasks=2)


# ------------------------------------------------------------------ thermal

def test_transient_matches_numpy_reference():
    rng = np.random.default_rng(0)
    trace = rng.uniform(0.0, 3.0, size=(50, 3))
    ref = thermal.simulate_trace(trace, dt_s=0.02)
    jx = np.asarray(transient_trace(trace, 0.02))
    np.testing.assert_allclose(jx, ref, rtol=1e-5, atol=1e-4)


def test_thermal_scan_converges_to_steady_state():
    power = np.array([3.0, 1.0, 0.5])
    expect = thermal.steady_state(power)
    trace = np.tile(power, (30000, 1))                # 30000 * 0.05s = 1500 s
    temps = np.asarray(transient_trace(trace, 0.05))
    np.testing.assert_allclose(temps[-1], expect, rtol=1e-3)
    # analytical jnp steady state agrees with the numpy oracle exactly-ish
    np.testing.assert_allclose(np.asarray(thermal_jax.steady_state(power)),
                               expect, rtol=1e-5)


def test_binned_power_conserves_energy():
    """∫ binned node power dt == the kernel's active+idle energy integral."""
    p = DesignPoint(2, 2, 1, 2, 0)
    apps = _apps()
    traces = _traces(2)
    batch = build_design_batch([p], apps)
    arrival, app_idx = stack_traces(traces)
    out = simulate_design_batch(batch, "etf", arrival, app_idx)
    for s in range(len(traces)):
        trace_kw, dt_us = binned_power_trace(
            out["start"][0, s], out["finish"][0, s], out["onpe"][0, s],
            out["scheduled"][0, s], batch.node_of_pe[0],
            batch.tables.power_active[0], batch.tables.power_idle[0],
            out["makespan_us"][0, s], bins=64)
        # node power (W) * bin width (us) * 1e-6 -> J, == kernel energy field
        e_binned = float(np.sum(np.asarray(trace_kw)) * np.asarray(dt_us)
                         * 1e6 * 1e-6)
        e_kernel = float(np.asarray(out["energy_j"])[0, s])
        assert e_binned == pytest.approx(e_kernel, rel=1e-3)


def test_peak_temperature_stable_for_long_bins():
    """Bin widths above the forward-Euler stability bound (~0.4 s for the
    LITTLE node) must not diverge: the exact linear-RC update is used."""
    rng = np.random.default_rng(3)
    trace = rng.uniform(0.0, 4.0, size=(32, 3))
    for dt in (1e-6, 0.1, 1.0, 60.0):
        peak = float(np.asarray(thermal_jax.peak_temperature(trace, dt)))
        assert np.isfinite(peak)
        assert thermal.T_AMBIENT_C - 1e-3 <= peak < 200.0
    # constant power at any dt stays pinned to the analytical steady state
    const = np.tile([3.0, 1.0, 0.5], (16, 1))
    expect = float(thermal.steady_state(const[0])[:3].max())
    got = float(np.asarray(thermal_jax.peak_temperature(const, 50.0)))
    assert got == pytest.approx(expect, rel=1e-4)


def test_peak_temperature_grid_monotone_in_power():
    """More loaded design (fewer, hotter big cores at fmax) runs hotter than
    an idle-ish LITTLE-only design; all temps are >= ambient."""
    points = [DesignPoint(4, 0, 0, 0, 0, big_freq_ghz=2.0),
              DesignPoint(0, 4, 0, 0, 0, little_freq_ghz=1.0)]
    apps = [wifi_tx()]
    traces = [poisson_trace(40.0, 16, ["wifi_tx"], seed=0)]
    batch = build_design_batch(points, apps)
    arrival, app_idx = stack_traces(traces)
    out = simulate_design_batch(batch, "etf", arrival, app_idx)
    temps = np.asarray(peak_temperature_grid(
        out, batch.node_of_pe, batch.tables.power_active,
        batch.tables.power_idle))
    assert temps.shape == (2, 1)
    assert np.all(temps >= thermal.T_AMBIENT_C - 1e-6)
    assert temps[0, 0] > temps[1, 0]


# ------------------------------------------------------------------- search

def test_evaluate_shapes_and_front():
    space = DesignSpace()
    pts = space.sample_lhs(8, seed=1)
    res = evaluate(pts, _apps(), _traces(2))
    assert res.objectives().shape == (8, 3)
    assert res.latency_per_trace_us.shape == (8, 2)
    mask = res.front_mask()
    assert mask.any() and mask.shape == (8,)


def test_successive_halving_prunes():
    space = DesignSpace()
    pts = space.sample_lhs(12, seed=2)
    res = successive_halving(pts, _apps(), _traces(3), eta=2,
                             min_survivors=4)
    assert res.num_designs == 6                       # 12 // eta
    assert set(res.points) <= set(pts)


def test_pareto_search_deterministic_and_grows():
    space = DesignSpace()
    kw = dict(rounds=2, batch_size=8, seed=5)
    a = pareto_search(space, [wifi_tx()],
                      [poisson_trace(20.0, 8, ["wifi_tx"], seed=0)], **kw)
    b = pareto_search(space, [wifi_tx()],
                      [poisson_trace(20.0, 8, ["wifi_tx"], seed=0)], **kw)
    assert a.archive.points == b.archive.points
    np.testing.assert_array_equal(a.archive.objectives(),
                                  b.archive.objectives())
    assert a.archive.num_designs > 8                  # refinement added points
    assert a.front.sum() >= 1
    assert len(a.rounds) == 2
