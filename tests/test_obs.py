"""repro.obs: telemetry timelines, Chrome traces, metrics (DESIGN.md §11).

Three contracts pinned here:

* **telemetry equality** — on comm-free integer-latency traces the ref
  kernel's in-loop window recording and the jax kernel's post-hoc telemetry
  scan produce the same (W, C) timelines (same tolerance discipline as
  tests/test_dtpm.py), for both the closed DTPM loop and static governors;
* **zero overhead** — ``telemetry=False`` runs are byte-identical to the
  pre-observability kernel: same output arrays, no extra compiles of the
  simulation program;
* **artifact schemas** — Chrome trace-event JSON validates (and the
  validator catches corruption), bench payloads and run manifests carry
  their schema tags, the report CLI renders/validates them.
"""
import json

import numpy as np
import pytest

from repro.core.applications import wifi_tx
from repro.core.dvfs import OndemandGovernor, get_governor
from repro.core.jobgen import deterministic_trace
from repro.core.resources import CommModel, make_soc_table2
from repro.core.schedulers import get_scheduler
from repro.core.simkernel_jax import (_COMPILES_DTPM, build_tables,
                                      simulate_jax, simulate_jax_dtpm)
from repro.core.simkernel_ref import simulate
from repro.obs import (Telemetry, TelemetryRecorder, bench_cli, chrome_trace,
                       metrics, validate_chrome_trace, write_chrome_trace)
from repro.obs.bench import BENCH_SCHEMA, rows_payload
from repro.obs.metrics import MANIFEST_SCHEMA
from repro.obs.telemetry import (TELEMETRY_SCHEMA, _bucket_pow2, domain_count,
                                 jax_dtpm_telemetry, jax_static_telemetry,
                                 num_windows_for, ref_static_telemetry)
from repro.scenario import Scenario, TraceSpec, run, sweep
from repro.scenario.sweep import compile_count

SCN = Scenario(apps=("wifi_tx",),
               trace=TraceSpec(rate_jobs_per_ms=25.0, num_jobs=24, seed=3))


def _comm_free_db():
    db = make_soc_table2()
    db.comm = CommModel(startup_us=0.0, bw_bytes_per_us=1e30)
    return db


# ------------------------------------------------ metrics registry

def test_counter_and_timer_registry():
    c = metrics.counter("test_obs.count")
    assert metrics.counter("test_obs.count") is c      # registry identity
    c.reset()
    assert c.inc() == 1 and c.inc(3) == 4
    assert c.value == 4 and int(c) == 4
    t = metrics.timer("test_obs.timer")
    with t:
        pass
    assert t.count >= 1 and t.last_s >= 0.0
    assert t.last_us == t.last_s * 1e6
    snap = metrics.snapshot()
    assert snap["counters"]["test_obs.count"] == 4
    assert "test_obs.timer" in snap["timers"]


def test_sweep_compile_count_is_obs_counter():
    """The legacy module attribute IS the registered counter; the deprecated
    one-element-list alias (``compile_count[0]``), kept for one release
    after the registry landed, is now gone."""
    assert compile_count is metrics.counter("scenario.sweep.compile_count")
    with pytest.raises(TypeError):
        compile_count[0]                            # noqa: B018 — alias removed
    with pytest.raises(TypeError):
        compile_count[0] = 7
    assert not hasattr(metrics.Counter, "__getitem__")
    assert not hasattr(metrics.Counter, "__setitem__")


def test_window_sizing_helpers():
    assert num_windows_for(100.0, 50.0) == 2           # exact multiple
    assert num_windows_for(101.0, 50.0) == 3
    assert num_windows_for(0.0, 50.0) == 0
    assert [_bucket_pow2(n) for n in (1, 2, 3, 5, 33)] == [1, 2, 4, 8, 64]


# ------------------------------------------------ telemetry equality

def test_dtpm_telemetry_ref_jax_agree():
    """Comm-free ondemand trace: the ref kernel's in-loop window recording
    equals the jax kernel's post-hoc telemetry scan — same OPP decision in
    every window, utilisation/power/temperature to float32 tolerance."""
    db = _comm_free_db()
    app = wifi_tx()
    trace = deterministic_trace(25.0, 64, ["wifi_tx"])
    gov = OndemandGovernor(sample_window_us=50.0)
    rec = TelemetryRecorder(gov.sample_window_us)
    ref = simulate(db, [app], trace, get_scheduler("etf"), gov,
                   telemetry=rec)
    tel_ref = rec.build(domain_count(db))
    tables = build_tables(db, [app], governor=gov)
    out = simulate_jax_dtpm(tables, "etf", trace.arrival_us, trace.app_index,
                            gov.policy())
    tel_jax = jax_dtpm_telemetry(tables, gov.policy(), out, trace.app_index)
    W = num_windows_for(ref.makespan_us, gov.sample_window_us)
    assert W > 0
    assert tel_ref.num_windows == tel_jax.num_windows == W
    assert tel_ref.num_domains == tel_jax.num_domains == domain_count(db)
    # the governor made the same OPP decision in every window
    np.testing.assert_array_equal(tel_ref.freq_idx, tel_jax.freq_idx)
    np.testing.assert_allclose(tel_ref.freq_ghz, tel_jax.freq_ghz, rtol=1e-6)
    np.testing.assert_allclose(tel_ref.util, tel_jax.util, rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(tel_ref.power_w, tel_jax.power_w, rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(tel_ref.temps_c, tel_jax.temps_c, rtol=1e-4)
    # the replayed timeline reproduces the kernel's inline RC peak
    assert tel_jax.peak_temp_c == pytest.approx(float(out["peak_temp_c"]),
                                                rel=1e-6)
    np.testing.assert_allclose(tel_ref.peak_temp_c, tel_jax.peak_temp_c,
                               rtol=1e-4)


def test_static_telemetry_ref_jax_agree():
    """Static governor: both backends replay the same window observables;
    the frequency columns are governor constants — exactly equal."""
    db = _comm_free_db()
    app = wifi_tx()
    trace = deterministic_trace(25.0, 64, ["wifi_tx"])
    gov = get_governor("performance")
    ref = simulate(db, [app], trace, get_scheduler("etf"), gov)
    tel_ref = ref_static_telemetry(db, ref, gov)
    tables = build_tables(db, [app], governor=gov)
    out = simulate_jax(tables, "etf", trace.arrival_us, trace.app_index)
    tel_jax = jax_static_telemetry(db, gov, tables, out, trace.app_index)
    assert tel_ref.num_windows == tel_jax.num_windows \
        == num_windows_for(ref.makespan_us, tel_ref.window_us)
    np.testing.assert_array_equal(tel_ref.freq_idx, tel_jax.freq_idx)
    np.testing.assert_array_equal(tel_ref.freq_ghz, tel_jax.freq_ghz)
    np.testing.assert_allclose(tel_ref.util, tel_jax.util, rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(tel_ref.power_w, tel_jax.power_w, rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(tel_ref.temps_c, tel_jax.temps_c, rtol=1e-4)


def test_telemetry_is_zero_overhead():
    """``telemetry=True`` must not touch the simulation: identical output
    arrays, and the DTPM program is NOT re-traced (the timeline is a
    separate scan over the already-computed schedule)."""
    scn = SCN.replace(governor="ondemand")
    r0 = run(scn, backend="jax")                       # telemetry off
    assert r0.telemetry is None
    n_dtpm = _COMPILES_DTPM.value
    r1 = run(scn, backend="jax", telemetry=True)
    assert _COMPILES_DTPM.value == n_dtpm              # no sim re-compile
    assert r1.telemetry is not None
    for key in ("scheduled", "start", "finish", "onpe", "onopp"):
        np.testing.assert_array_equal(np.asarray(r0.raw[key]),
                                      np.asarray(r1.raw[key]))
    assert r0.avg_latency_us == r1.avg_latency_us
    assert r0.energy_j == r1.energy_j
    assert r0.peak_temp_c == r1.peak_temp_c
    # the Scenario field spells the same request declaratively
    r2 = run(scn.replace(telemetry=True), backend="jax")
    assert r2.telemetry is not None
    assert r2.telemetry.num_windows == r1.telemetry.num_windows


def test_result_manifest_attached():
    for backend in ("ref", "jax"):
        man = run(SCN, backend=backend).manifest
        assert man["schema"] == MANIFEST_SCHEMA
        assert man["backend"] == backend
        assert man["scenario"] == SCN.label()
        assert len(man["scenario_hash"]) == 12
        assert man["jit_compile_count"] >= 0
        assert "counters" in man["metrics"] and "timers" in man["metrics"]
        assert "timestamp" in man and "device_platform" in man


def test_sweep_telemetry_lanes_match_run():
    """Sweep timelines replay the grid outputs — every lane equals its
    single-scenario ``run(..., telemetry=True)``, without re-simulating."""
    scn = SCN.replace(governor="ondemand")
    params = [(("up_threshold", 0.6),), (("up_threshold", 0.9),)]
    sr = sweep(scn, axes={"governor_params": params}, telemetry=True)
    assert sr.telemetry.shape == (2,)
    for k in (0, 1):
        single = run(scn.replace(governor_params=params[k]), backend="jax",
                     telemetry=True)
        lane = sr.telemetry[k]
        assert isinstance(lane, Telemetry)
        assert lane.num_windows == single.telemetry.num_windows
        np.testing.assert_array_equal(lane.freq_idx,
                                      single.telemetry.freq_idx)
        np.testing.assert_allclose(lane.temps_c, single.telemetry.temps_c,
                                   rtol=1e-6)
        np.testing.assert_allclose(lane.util, single.telemetry.util,
                                   rtol=1e-6)
    # telemetry off -> field stays None (no silent cost)
    assert sweep(scn, axes={"governor_params": params[:1]}).telemetry is None


def test_sweep_telemetry_static_and_ref_lanes():
    sr = sweep(SCN, axes={"seed": [0, 1]}, telemetry=True)
    assert sr.telemetry.shape == (2,)
    single = run(SCN.with_seed(1), backend="jax", telemetry=True)
    np.testing.assert_array_equal(sr.telemetry[1].freq_ghz,
                                  single.telemetry.freq_ghz)
    np.testing.assert_allclose(sr.telemetry[1].temps_c,
                               single.telemetry.temps_c, rtol=1e-6)
    sr_ref = sweep(SCN, axes={"seed": [0]}, backend="ref", telemetry=True)
    assert isinstance(sr_ref.telemetry[0], Telemetry)
    assert sr_ref.telemetry[0].num_windows > 0


def test_telemetry_json_roundtrip_and_props():
    res = run(SCN.replace(governor="ondemand"), backend="ref",
              telemetry=True)
    tel = res.telemetry
    d = tel.to_dict()
    assert d["schema"] == TELEMETRY_SCHEMA
    back = Telemetry.from_dict(json.loads(json.dumps(d)))
    np.testing.assert_array_equal(back.freq_idx, tel.freq_idx)
    np.testing.assert_allclose(back.temps_c, tel.temps_c, rtol=1e-6)
    with pytest.raises(ValueError, match="schema"):
        Telemetry.from_dict({"schema": "bogus"})
    assert np.all(np.diff(tel.time_us) > 0)            # window-end timestamps
    assert tel.time_us[-1] == pytest.approx(tel.num_windows * tel.window_us)
    assert tel.peak_temp_c == float(np.max(tel.temps_c[:, :3]))
    assert tel.avg_power_w > 0.0


# ------------------------------------------------ Chrome trace (Perfetto)

def test_chrome_trace_schema_valid(tmp_path):
    scn = SCN.replace(governor="ondemand")
    res = run(scn, backend="ref", telemetry=True)
    db = scn.soc()
    tr = chrome_trace(db, res.raw, apps=scn.applications(),
                      trace=scn.job_trace(), telemetry=res.telemetry)
    assert validate_chrome_trace(tr) == []
    events = tr["traceEvents"]
    # one thread-name track per PE, matched B/E pair per committed task
    names = [e for e in events if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(names) == db.num_pes
    n_b = sum(e["ph"] == "B" for e in events)
    n_e = sum(e["ph"] == "E" for e in events)
    assert n_b == n_e == len(res.raw.records)
    # counter tracks carry the telemetry timelines
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert counters == {"freq_ghz", "util", "temp_c"}
    # task names resolve through the app graph
    assert any(e["name"].startswith("wifi_tx.") for e in events
               if e["ph"] == "B")
    path = tmp_path / "trace.json"
    write_chrome_trace(path, tr)
    assert validate_chrome_trace(json.loads(path.read_text())) == []


def test_chrome_trace_validator_catches_corruption():
    ok = {"traceEvents": [
        {"name": "t", "ph": "B", "pid": 0, "tid": 0, "ts": 1.0},
        {"name": "t", "ph": "E", "pid": 0, "tid": 0, "ts": 2.0}]}
    assert validate_chrome_trace(ok) == []
    assert validate_chrome_trace({}) == ["missing or non-list 'traceEvents'"]
    unmatched = {"traceEvents": ok["traceEvents"][:1]}
    assert any("unmatched 'B'" in e for e in validate_chrome_trace(unmatched))
    backwards = {"traceEvents": [
        {"name": "t", "ph": "B", "pid": 0, "tid": 0, "ts": 2.0},
        {"name": "t", "ph": "E", "pid": 0, "tid": 0, "ts": 1.0}]}
    errs = validate_chrome_trace(backwards)
    assert any("non-monotonic" in e for e in errs)
    assert any("precedes its 'B'" in e for e in errs)
    orphan_end = {"traceEvents": [
        {"name": "t", "ph": "E", "pid": 0, "tid": 0, "ts": 1.0}]}
    assert any("no open 'B'" in e for e in validate_chrome_trace(orphan_end))
    missing = {"traceEvents": [{"ph": "B", "pid": 0, "tid": 0, "ts": 0.0}]}
    assert any("missing key 'name'" in e
               for e in validate_chrome_trace(missing))


# ------------------------------------------------ bench harness + report CLI

def test_bench_cli_json_payload(tmp_path, capsys):
    path = tmp_path / "BENCH_unit.json"
    rc = bench_cli(lambda: [("unit/x", 1.5, "note")], "unit", "doc",
                   ["--json", str(path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "name,value,derived" in out and "unit/x,1.5000,note" in out
    payload = json.loads(path.read_text())
    assert payload["schema"] == BENCH_SCHEMA
    assert payload["manifest"]["schema"] == MANIFEST_SCHEMA
    assert payload["manifest"]["bench"] == "unit"
    assert payload["manifest"]["wall_s"] >= 0.0
    assert payload["rows"] == [
        {"name": "unit/x", "value": 1.5, "derived": "note"}]
    # rows_payload is the same serialisation benchmarks/run.py could reuse
    again = rows_payload([("unit/x", 1.5, "note")], "unit", 0.0)
    assert again["rows"] == payload["rows"]


def test_report_cli_trace_validate_render(tmp_path, capsys):
    from repro.obs import report
    trace_p = tmp_path / "TRACE.json"
    tel_p = tmp_path / "TELEMETRY.json"
    rc = report.main(["--jobs", "12", "--governor", "ondemand",
                      "--trace", str(trace_p), "--telemetry", str(tel_p)])
    assert rc == 0
    assert validate_chrome_trace(json.loads(trace_p.read_text())) == []
    assert json.loads(tel_p.read_text())["schema"] == TELEMETRY_SCHEMA
    assert report.main(["--validate", str(trace_p)]) == 0
    out = capsys.readouterr().out
    assert "valid Chrome trace" in out and "perfetto" in out
    # rendering: bench payload + telemetry dump summaries
    bench_p = tmp_path / "BENCH_unit.json"
    bench_p.write_text(json.dumps(rows_payload([("a/b", 2.0, "d")],
                                               "unit", 0.1)))
    assert report.main([str(bench_p), str(tel_p)]) == 0
    out = capsys.readouterr().out
    assert "manifest:" in out and "rows (1):" in out and "windows" in out
    # corruption makes --validate exit non-zero
    bad = tmp_path / "BAD.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"name": "t", "ph": "B", "pid": 0, "tid": 0, "ts": 0.0}]}))
    assert report.main(["--validate", str(bad)]) == 1
