"""Elastic fault tolerance: a checkpoint written on one topology must resume
on a DIFFERENT mesh (scale-up) and keep training — run in a subprocess with a
forced 8-device CPU topology."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, reduced
    from repro.data import SyntheticLMPipeline
    from repro.launch.mesh import rules_for
    from repro.launch.steps import init_opt_state, make_train_step
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.sharding import use_mesh

    cfg = reduced(get_config("granite-3-8b"))
    B, S, LR = 8, 32, 1e-3
    ckpt = tempfile.mkdtemp()

    def run_steps(mesh, start, stop, resume):
        rules = rules_for(mesh, batch_size=B)
        with use_mesh(mesh, rules):
            model = build_model(cfg)
            pipe = SyntheticLMPipeline(cfg.vocab_size, B, S, seed=0)
            mgr = CheckpointManager(ckpt)
            if resume:
                model.abstract_params()
                # place every leaf onto the CURRENT mesh via param specs
                pspecs = model.param_pspecs()
                shardings = jax.tree.map(
                    lambda ps: NamedSharding(mesh, ps), pspecs,
                    is_leaf=lambda x: isinstance(x, P))
                state, meta = mgr.restore(
                    shardings={"params": shardings,
                               "opt": {"master": shardings, "mu": shardings,
                                       "nu": shardings,
                                       "step": NamedSharding(mesh, P())}})
                params, opt = state["params"], state["opt"]
                pipe.load_state_dict(meta["data"])
            else:
                params = model.init_params(jax.random.PRNGKey(0))
                opt = init_opt_state(params)
            step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=LR)),
                              donate_argnums=(0, 1))
            losses = []
            for t in range(start, stop):
                b = pipe.batch_at(t)
                pipe.state.step = t + 1
                params, opt, m = step_fn(params, opt, b)
                losses.append(float(m["loss"]))
            mgr.save(stop, {"params": params, "opt": opt},
                     meta={"data": pipe.state_dict()})
            return params, losses

    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    p1, l1 = run_steps(mesh1, 0, 5, resume=False)

    # scale UP: resume the same run on a (2, 4) mesh
    mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    p2, l2 = run_steps(mesh2, 5, 10, resume=True)

    # reference: 10 uninterrupted steps on the small mesh
    import shutil; shutil.rmtree(ckpt); os.makedirs(ckpt)
    p3, l3 = run_steps(mesh1, 0, 10, resume=False)

    err = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32))))
              for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)))
    print(f"RESULT losses={l2[-1]:.4f}/{l3[-1]:.4f} max_param_err={err:.2e}")
    assert np.isfinite(l2).all()
    assert err < 5e-3, err          # same trajectory across topologies
""")


def test_elastic_resume_across_meshes():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    assert "RESULT" in out.stdout
