"""Quickstart: the paper in 60 seconds.

Simulates a WiFi-TX workload on the Table-2 SoC with all three built-in
schedulers, prints the Fig-3 sweep, an ASCII Gantt chart, and energy numbers.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (TableScheduler, get_governor, get_scheduler,
                        make_soc_table2, poisson_trace, reports, simulate,
                        solve_optimal_table, wifi_tx)


def main():
    db = make_soc_table2()
    app = wifi_tx()
    table = solve_optimal_table(db, app)
    print("ILP-optimal single-job table:",
          {t: db.pes[pe].name for (_, t), pe in sorted(table.items())}, "\n")

    print(f"{'rate (jobs/ms)':>15} {'MET':>9} {'ETF':>9} {'ILP':>9}   (avg job latency, us)")
    for rate in [1, 10, 20, 40, 60, 80]:
        row = []
        for sched in [get_scheduler("met"), get_scheduler("etf"),
                      TableScheduler(table)]:
            vals = [simulate(db, [app],
                             poisson_trace(rate, 100, ["wifi_tx"], seed=s),
                             sched).avg_job_latency_us for s in range(3)]
            row.append(np.mean(vals))
        print(f"{rate:>15} {row[0]:>9.1f} {row[1]:>9.1f} {row[2]:>9.1f}")

    print("\nSchedule (ETF, first jobs) — one row per PE, digits = job id:")
    res = simulate(db, [app], poisson_trace(30, 12, ["wifi_tx"], seed=0),
                   get_scheduler("etf"))
    print(reports.gantt_ascii(db, res, width=90))

    for gov in ["performance", "powersave", "ondemand"]:
        res = simulate(db, [app], poisson_trace(20, 100, ["wifi_tx"], seed=0),
                       get_scheduler("etf"), get_governor(gov))
        print(f"governor={gov:<12} latency={res.avg_job_latency_us:7.1f}us "
              f"energy={res.energy.total_energy_mj:6.3f}mJ "
              f"avg_power={res.energy.avg_power_w:5.2f}W")


if __name__ == "__main__":
    main()
