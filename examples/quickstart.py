"""Quickstart: the paper in 60 seconds — through the unified Scenario API.

One declarative ``Scenario`` wires SoC, workload, scheduler and governor;
``run()`` simulates it, ``sweep()`` cross-products axes.  Prints the Fig-3
sweep, an ASCII Gantt chart, and energy numbers.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import reports
from repro.scenario import Scenario, TraceSpec, run, sweep

BASE = Scenario(apps=("wifi_tx",))
RATES = [1, 10, 20, 40, 60, 80]
SEEDS = [0, 1, 2]


def main():
    db = BASE.soc()
    table = BASE.replace(scheduler="table").schedule_table()
    print("ILP-optimal single-job table:",
          {t: db.pes[pe].name for (_, t), pe in sorted(table.items())}, "\n")

    curves = {}
    for policy in ["met", "etf", "table"]:
        sr = sweep(BASE.replace(scheduler=policy),
                   axes={"rate": RATES, "seed": SEEDS}, backend="ref")
        curves[policy] = sr.avg_latency_us.mean(axis=1)
    print(f"{'rate (jobs/ms)':>15} {'MET':>9} {'ETF':>9} {'ILP':>9}   (avg job latency, us)")
    for i, rate in enumerate(RATES):
        print(f"{rate:>15} {curves['met'][i]:>9.1f} {curves['etf'][i]:>9.1f} "
              f"{curves['table'][i]:>9.1f}")

    print("\nSchedule (ETF, first jobs) — one row per PE, digits = job id:")
    res = run(BASE.replace(trace=TraceSpec(rate_jobs_per_ms=30, num_jobs=12)),
              backend="ref")
    print(reports.gantt_ascii(db, res.raw, width=90))

    for gov in ["performance", "powersave", "ondemand"]:
        res = run(BASE.replace(governor=gov,
                               trace=TraceSpec(rate_jobs_per_ms=20,
                                               num_jobs=100)),
                  backend="ref")
        print(f"governor={gov:<12} latency={res.avg_latency_us:7.1f}us "
              f"energy={res.energy_j:8.5f}J "
              f"avg_power={res.avg_power_w:5.2f}W "
              f"T_steady_peak={res.peak_temp_c:5.1f}C")


if __name__ == "__main__":
    main()
