"""Design-space exploration: 64 SoC designs × 4 traces in one jitted call.

Sweeps a latin-hypercube sample of the big/LITTLE/accelerator design space
under a WiFi TX+RX workload declared by one ``Scenario``, prints the
non-dominated (latency, energy, peak-temperature) front, then spot-checks
three designs from the padded batch against per-point
``run(..., backend="jax")`` — bit-for-bit.

    PYTHONPATH=src python examples/dse_pareto.py
"""
import time

import numpy as np

from repro.dse import DesignSpace, evaluate, format_front
from repro.scenario import Scenario, TraceSpec, run, sweep
from repro.scenario.sweep import compile_count

NUM_DESIGNS = 64
NUM_TRACES = 4
NUM_JOBS = 32
RATE = 20.0          # jobs/ms
POLICY = "etf"

BASE = Scenario(apps=("wifi_tx", "wifi_rx"), scheduler=POLICY,
                governor="design",
                trace=TraceSpec(rate_jobs_per_ms=RATE, num_jobs=NUM_JOBS))


def main():
    points = DesignSpace().sample_lhs(NUM_DESIGNS, seed=0)
    seeds = list(range(NUM_TRACES))
    traces = [BASE.with_seed(s).job_trace() for s in seeds]

    t0 = time.perf_counter()
    result = evaluate(points, BASE.applications(), traces, policy=POLICY)
    dt = time.perf_counter() - t0
    print(format_front(result))
    print(f"{NUM_DESIGNS} designs x {NUM_TRACES} traces "
          f"({NUM_DESIGNS * NUM_TRACES} simulations) in {dt:.2f}s "
          f"(incl. jit compile)\n")

    # -- padded-sweep vs per-point run() spot check (bit-for-bit) ----------
    n0 = compile_count.value
    sr = sweep(BASE, axes={"design": points, "seed": seeds})
    print(f"sweep over design x seed: shape {sr.shape}, "
          f"{compile_count.value - n0} compiled program(s)")
    rng = np.random.default_rng(1)
    for d in rng.choice(NUM_DESIGNS, size=3, replace=False):
        p = points[d]
        exact = True
        for s in seeds:
            ref = run(BASE.replace(design=p).with_seed(s), backend="jax")
            exact &= bool(sr.avg_latency_us[d, s] == ref.avg_latency_us)
            exact &= bool(sr.makespan_us[d, s] == ref.makespan_us)
            exact &= bool(sr.energy_j[d, s] == ref.energy_j)
            exact &= bool(np.all(
                sr.busy_per_pe_us[d, s, :p.num_pes]
                == np.asarray(ref.raw["busy_per_pe_us"])))
        print(f"spot-check {p.label():>26}: padded sweep == per-point "
              f"run(backend='jax') (bit-for-bit): {exact}")
        assert exact, f"batched result diverged for {p.label()}"


if __name__ == "__main__":
    main()
