"""Design-space exploration: 64 SoC designs × 4 traces in one jitted call.

Sweeps a latin-hypercube sample of the big/LITTLE/accelerator design space
under a WiFi TX+RX workload, prints the non-dominated
(latency, energy, peak-temperature) front, then spot-checks three designs
from the padded batch against per-design ``simulate_jax`` — bit-for-bit.

    PYTHONPATH=src python examples/dse_pareto.py
"""
import time

import numpy as np

from repro.core import build_tables, get_application, poisson_trace, \
    simulate_jax
from repro.dse import (DesignSpace, build_design_batch, evaluate,
                       format_front, simulate_design_batch, stack_traces)

NUM_DESIGNS = 64
NUM_TRACES = 4
NUM_JOBS = 32
RATE = 20.0          # jobs/ms
POLICY = "etf"
APPS = ["wifi_tx", "wifi_rx"]


def main():
    apps = [get_application(n) for n in APPS]
    traces = [poisson_trace(RATE, NUM_JOBS, APPS, seed=s)
              for s in range(NUM_TRACES)]
    space = DesignSpace()
    points = space.sample_lhs(NUM_DESIGNS, seed=0)
    batch = build_design_batch(points, apps)

    t0 = time.perf_counter()
    result = evaluate(points, apps, traces, policy=POLICY, batch=batch)
    dt = time.perf_counter() - t0
    print(format_front(result))
    print(f"{NUM_DESIGNS} designs x {NUM_TRACES} traces "
          f"({NUM_DESIGNS * NUM_TRACES} simulations) in {dt:.2f}s "
          f"(incl. jit compile)\n")

    # -- padded-batch vs per-design spot check (bit-for-bit) ---------------
    arrival, app_idx = stack_traces(traces)
    out = simulate_design_batch(batch, POLICY, arrival, app_idx)
    rng = np.random.default_rng(1)
    for d in rng.choice(NUM_DESIGNS, size=3, replace=False):
        p = points[d]
        tables = build_tables(p.to_db(), apps, governor=p.governor())
        exact = True
        for s, tr in enumerate(traces):
            ref = simulate_jax(tables, POLICY, tr.arrival_us, tr.app_index)
            for key in ("avg_job_latency_us", "makespan_us", "energy_mj"):
                exact &= bool(np.asarray(out[key])[d, s]
                              == np.asarray(ref[key]))
            exact &= bool(np.all(
                np.asarray(out["busy_per_pe_us"])[d, s, :p.num_pes]
                == np.asarray(ref["busy_per_pe_us"])))
        print(f"spot-check {p.label():>26}: padded-batch == per-design "
              f"simulate_jax (bit-for-bit): {exact}")
        assert exact, f"batched result diverged for {p.label()}"


if __name__ == "__main__":
    main()
