"""End-to-end training driver example.

Tiny preset (CPU, runs in ~a minute):
    PYTHONPATH=src python examples/train_lm.py

Demonstrating fault tolerance (injected preemption + resume):
    PYTHONPATH=src python examples/train_lm.py --demo-preemption

Full-scale config on a real pod (same code path; needs TPU hardware):
    python examples/train_lm.py --arch granite-3-8b --preset full \
        --production-mesh --batch 256 --seq 4096 --steps 1000
"""
import argparse
import tempfile

from repro.launch.train import train, train_with_retries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--demo-preemption", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    if args.demo_preemption:
        with tempfile.TemporaryDirectory() as d:
            print("== run with injected preemption at step 60; the retry "
                  "loop restores from the step-40 checkpoint ==")
            _, losses, wd = train_with_retries(
                arch=args.arch, preset=args.preset, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=d, ckpt_every=40,
                fail_at=60)
            print(f"final loss {losses[-1]:.4f}; "
                  f"straggler events: {len(wd.events)}")
        return

    _, losses, wd = train(arch=args.arch, preset=args.preset,
                          steps=args.steps, batch=args.batch, seq=args.seq,
                          production_mesh=args.production_mesh)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps; "
          f"straggler events: {len(wd.events)}")


if __name__ == "__main__":
    main()
