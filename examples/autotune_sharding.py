"""The paper ↔ pod bridge: DS3-driven design-space exploration of the pod.

Exactly the paper's methodology, one level up: the *resource database* is
populated with per-layer costs derived from the dry-run roofline (or the
analytic model when no dry-run artifacts exist), candidate pod layouts play
the role of candidate SoC configurations, and the simulation kernel + ETF
scheduler evaluate a training-step workload against each.  The launcher then
picks the layout with the best simulated step time — "sweeping the
configuration space to determine the most suitable ... for a given
architecture" (paper §3).

    PYTHONPATH=src python examples/autotune_sharding.py --arch granite-3-8b
"""
import argparse

from repro.core import (Application, Task, ResourceDB, PE, deterministic_trace,
                        get_scheduler, simulate)
from repro.core.resources import CommModel
from repro.configs import get_config, get_shape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.roofline import load_cell, model_flops

# candidate pod layouts: (name, data_par, model_par, accum)
CANDIDATES = [
    ("dp32_tp8", 32, 8, 8),
    ("dp16_tp16", 16, 16, 16),
    ("dp8_tp32", 8, 32, 32),
]


def layer_costs_us(arch: str, shape_name: str, dp: int, tp: int):
    """Per-layer (compute, collective) cost estimate for a layout."""
    cfg = get_config(arch)
    rec = load_cell(arch, shape_name, "pod16x16")
    chips = dp * tp
    if rec is not None and rec.get("extrapolated"):
        flops_dev = rec["extrapolated"]["flops"] * 256 / chips
        wire_dev = sum(rec["extrapolated"]["wire"].values()) * 256 / chips
        # TP collectives scale with tp relative to the measured 16-way layout
        wire_dev *= tp / 16
    else:
        flops_dev = model_flops(arch, shape_name) / chips * 1.4  # remat tax
        wire_dev = flops_dev * 0.002                              # heuristic
    n = cfg.num_layers + cfg.num_encoder_layers
    comp_us = flops_dev / PEAK_FLOPS_BF16 / n * 1e6
    coll_us = wire_dev / ICI_BW / n * 1e6
    return comp_us, coll_us


def build_soc(name: str, comp_us: float, coll_us: float, n_stages: int = 4):
    """Model the pod's model-parallel groups as PEs; the collective cost is
    folded into the task latency (it serialises with compute per layer)."""
    pes = [PE(i, "A15", cluster=0, name=f"{name}-grp{i}")
           for i in range(n_stages)]
    profiles = {"layer": {"A15": comp_us + coll_us}}
    return ResourceDB(pes, profiles, CommModel(0.0, 1e12))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    n_layers = cfg.num_layers + cfg.num_encoder_layers
    # training step = chain DAG of layer tasks (the paper's job)
    tasks = tuple(Task("layer", i, (i - 1,) if i else (), 1024.0)
                  for i in range(min(n_layers, 16)))
    app = Application("train_step", tasks)

    print(f"autotuning {args.arch} × {args.shape} over {len(CANDIDATES)} "
          f"layouts (DS3 ETF simulation, {args.steps} microbatch chains):\n")
    best = None
    for name, dp, tp, accum in CANDIDATES:
        comp, coll = layer_costs_us(args.arch, args.shape, dp, tp)
        # per-microbatch layer cost: the step's work divides over `accum`
        db = build_soc(name, comp * n_layers / len(tasks) / accum,
                       coll * n_layers / len(tasks) / accum)
        # `accum` microbatch chains injected together: ETF pipelines them
        # across the model-parallel groups (the paper's job-interleaving)
        trace = deterministic_trace(0.001, accum, ["train_step"])
        res = simulate(db, [app], trace, get_scheduler("etf"))
        step_ms = res.makespan_us / 1e3
        print(f"  {name:<10} per-layer comp={comp:8.1f}us coll={coll:7.1f}us"
              f" -> simulated step {step_ms:9.2f} ms")
        if best is None or step_ms < best[1]:
            best = (name, step_ms)
    print(f"\nselected layout: {best[0]}  ({best[1]:.2f} ms/step simulated)")


if __name__ == "__main__":
    main()
